"""reprolint core: source model, findings, suppressions, and the run loop.

Pieces
------
* :class:`Finding` — one checker hit, carrying a *stable key* (no line
  numbers) so the committed baseline survives unrelated edits.
* :class:`SourceFile` — a parsed module: AST, symbol table (qualnames with
  line ranges), and the suppression index built from ``# reprolint:``
  comments (tokenize-based, so strings containing the marker don't count).
* :class:`Project` — the set of files under analysis plus the repo root;
  checkers that need cross-file context (kind registry vs. emit sites,
  kernels vs. their tests) resolve it here.
* :func:`run_checkers` — run every checker, apply suppressions, and return
  ``(findings, suppressed)``.

Suppression grammar (checker names comma-separated, ``all`` wildcard;
everything after ``--`` is a human justification)::

    x = risky()               # reprolint: disable=<check> -- why it's fine
    def f():                  # reprolint: disable=<check> -- whole symbol
    # reprolint: disable-file=<check>

A comment on a ``def``/``class`` header line (or on a bare comment line
directly above one) suppresses the check for the whole symbol body; any
other placement suppresses only its own line.  A finding that carries
``extra_lines`` (e.g. every read site of an asymmetric knob) is suppressed
when *any* of its lines is — acknowledging one site acknowledges the knob.
"""

from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SEVERITIES = ("error", "warning")

_DISABLE_RE = re.compile(
    r"#\s*reprolint:\s*(?P<kind>disable(?:-file)?)\s*=\s*"
    r"(?P<checks>[a-z0-9_,\-\s]+?)(?:\s*--.*)?$"
)


@dataclass(frozen=True)
class Finding:
    """One checker hit.

    ``key`` is the stable identity used for baseline matching — it must not
    contain line numbers (those drift with every edit); ``(check, path,
    symbol, key)`` identifies the finding across revisions.
    """

    check: str
    path: str                      # repo-root-relative, posix separators
    line: int
    symbol: str                    # "Class.method", "Class", or "<module>"
    message: str
    key: str
    severity: str = "error"
    extra_lines: Tuple[int, ...] = ()   # further sites; any suppresses

    @property
    def identity(self) -> Tuple[str, str, str, str]:
        return (self.check, self.path, self.symbol, self.key)

    def to_dict(self) -> dict:
        return {
            "check": self.check,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "key": self.key,
            "message": self.message,
            "severity": self.severity,
        }


@dataclass
class _Suppression:
    checks: Set[str]
    start: int
    end: int                       # inclusive line range the disable covers


class SourceFile:
    """One parsed module plus its suppression index."""

    def __init__(self, root: Path, path: Path):
        self.abspath = path
        self.relpath = path.relative_to(root).as_posix()
        self.text = path.read_text()
        self.tree = ast.parse(self.text, filename=str(path))
        self.lines = self.text.splitlines()
        self._symbols = self._index_symbols()
        self.file_disables: Set[str] = set()
        self._suppressions: List[_Suppression] = []
        self._index_suppressions()

    # -- symbols ----------------------------------------------------------
    def _index_symbols(self) -> List[Tuple[str, int, int]]:
        out: List[Tuple[str, int, int]] = []

        def walk(node: ast.AST, prefix: str):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                    qual = f"{prefix}.{child.name}" if prefix else child.name
                    out.append((qual, child.lineno, child.end_lineno or
                                child.lineno))
                    walk(child, qual)
                else:
                    walk(child, prefix)

        walk(self.tree, "")
        return out

    def symbol_at(self, line: int) -> str:
        """Innermost enclosing def/class qualname, or ``<module>``."""
        best = "<module>"
        best_span = float("inf")
        for qual, start, end in self._symbols:
            if start <= line <= end and (end - start) < best_span:
                best, best_span = qual, end - start
        return best

    # -- suppressions -----------------------------------------------------
    def _symbol_header_span(self, line: int) -> Optional[Tuple[int, int]]:
        """If ``line`` sits on a def/class header (or the bare-comment line
        directly above one), return that symbol's (start, end)."""
        for qual, start, end in self._symbols:
            if line == start:
                return start, end
            if line == start - 1:
                stripped = self.lines[line - 1].strip() \
                    if line - 1 < len(self.lines) else ""
                if stripped.startswith("#"):
                    return start, end
        return None

    def _index_suppressions(self):
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.text).readline)
            comments = [(t.start[0], t.string) for t in tokens
                        if t.type == tokenize.COMMENT]
        except tokenize.TokenError:
            comments = [(i + 1, ln) for i, ln in enumerate(self.lines)
                        if "#" in ln]
        for lineno, comment in comments:
            m = _DISABLE_RE.search(comment)
            if not m:
                continue
            checks = {c.strip() for c in m.group("checks").split(",")
                      if c.strip()}
            if m.group("kind") == "disable-file":
                self.file_disables |= checks
                continue
            span = self._symbol_header_span(lineno)
            if span is not None:
                self._suppressions.append(_Suppression(checks, *span))
            else:
                self._suppressions.append(_Suppression(checks, lineno, lineno))

    def is_line_suppressed(self, check: str, line: int) -> bool:
        if {"all", check} & self.file_disables:
            return True
        for sup in self._suppressions:
            if sup.start <= line <= sup.end and {"all", check} & sup.checks:
                return True
        return False

    def is_suppressed(self, finding: Finding) -> bool:
        return any(self.is_line_suppressed(finding.check, line)
                   for line in (finding.line, *finding.extra_lines))


class Project:
    """The file set under analysis, keyed by repo-relative path."""

    def __init__(self, root: Path, paths: Sequence[Path]):
        self.root = Path(root).resolve()
        self.files: List[SourceFile] = []
        self.errors: List[str] = []
        seen: Set[Path] = set()
        for p in self._expand(paths):
            if p in seen:
                continue
            seen.add(p)
            try:
                self.files.append(SourceFile(self.root, p))
            except SyntaxError as exc:   # real parse error: surface, don't die
                self.errors.append(f"{p}: {exc}")
        self._by_rel = {f.relpath: f for f in self.files}

    @staticmethod
    def _expand(paths: Sequence[Path]) -> List[Path]:
        out: List[Path] = []
        for p in paths:
            p = Path(p).resolve()
            if p.is_dir():
                out.extend(sorted(
                    f for f in p.rglob("*.py")
                    if "__pycache__" not in f.parts
                    and not any(part.startswith(".") for part in f.parts)))
            elif p.suffix == ".py":
                out.append(p)
        return out

    def file(self, relpath: str) -> Optional[SourceFile]:
        return self._by_rel.get(relpath)

    def files_named(self, name: str) -> List[SourceFile]:
        return [f for f in self.files if Path(f.relpath).name == name]

    def extra_files(self, subdir: str) -> List[SourceFile]:
        """Parse files from ``root/subdir`` on demand (e.g. ``tests/`` for
        the kernel-test cross-reference) without adding them to the scanned
        set — findings are never anchored in extra files."""
        d = self.root / subdir
        if not d.is_dir():
            return []
        out = []
        for p in sorted(d.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            if p.resolve().as_posix() in {f.abspath.as_posix()
                                          for f in self.files}:
                out.append(self._by_rel[p.resolve().relative_to(
                    self.root).as_posix()])
                continue
            try:
                out.append(SourceFile(self.root, p.resolve()))
            except SyntaxError:
                continue
        return out


class Checker:
    """Base class: subclasses set ``name``/``checks`` and implement
    :meth:`run` returning raw findings (suppressions applied by the
    caller)."""

    name: str = "base"
    checks: Tuple[str, ...] = ()
    description: str = ""

    def run(self, project: Project) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError


def run_checkers(project: Project, checkers: Iterable[Checker],
                 only: Optional[Set[str]] = None,
                 ) -> Tuple[List[Finding], List[Finding]]:
    """Run checkers over the project; returns ``(active, suppressed)``,
    both sorted by (path, line, check, key)."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for checker in checkers:
        for finding in checker.run(project):
            if only is not None and finding.check not in only:
                continue
            src = project.file(finding.path)
            if src is not None and src.is_suppressed(finding):
                suppressed.append(finding)
            else:
                active.append(finding)
    order = lambda f: (f.path, f.line, f.check, f.key)  # noqa: E731
    return sorted(active, key=order), sorted(suppressed, key=order)


# -- shared AST helpers used by several checkers ---------------------------

def attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None for non-name-rooted chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """A tuple/list literal of string constants, else None."""
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            s = const_str(elt)
            if s is None:
                return None
            out.append(s)
        return tuple(out)
    return None


def class_defs(src: SourceFile) -> List[ast.ClassDef]:
    return [n for n in ast.walk(src.tree) if isinstance(n, ast.ClassDef)]


def dataclass_fields(cls: ast.ClassDef) -> List[Tuple[str, int, str]]:
    """(name, lineno, annotation-source) per class-level annotated field."""
    out = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            ann = ast.unparse(stmt.annotation)
            out.append((stmt.target.id, stmt.lineno, ann))
    return out
