"""reprolint — repo-specific static analysis for the serving simulator.

The simulator lives by a handful of invariants that ordinary linters cannot
see: every scheduling knob must be threaded through BOTH the per-slot
reference decode path and the vectorized/event-leap path, every per-replica
counter must survive the cluster merge and reach an exporter, scheduling
decisions must never depend on set iteration order or wall clocks, and every
Pallas kernel must ship with an XLA reference twin plus an interpret-vs-xla
test.  ``reprolint`` encodes those invariants as AST checkers with a
committed baseline (new findings fail CI, pre-existing ones don't) and
``# reprolint: disable=<check>`` suppressions for deliberate exceptions.

Run it as ``python -m tools.reprolint src/``; see ``docs/static-analysis.md``
for the checker catalog and workflow.
"""

from tools.reprolint.core import (  # noqa: F401
    Finding,
    Project,
    SourceFile,
    run_checkers,
)

__version__ = "1.0"
