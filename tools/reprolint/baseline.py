"""Baseline load/save/diff — the "new findings fail, old ones don't" gate.

The baseline is a committed JSON multiset of finding identities
``(check, path, symbol, key)``.  ``key`` is checker-chosen and line-free,
so reformatting or unrelated edits don't churn the baseline; moving a
finding to another symbol or file *does* count as new (it is new code).
"""

from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import List, Sequence, Tuple

from tools.reprolint.core import Finding

BASELINE_VERSION = 1

Identity = Tuple[str, str, str, str]


def _identity(entry: dict) -> Identity:
    return (entry["check"], entry["path"], entry["symbol"], entry["key"])


def load_baseline(path: Path) -> List[dict]:
    doc = json.loads(Path(path).read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline version "
                         f"{doc.get('version')!r}")
    entries = doc.get("findings")
    if not isinstance(entries, list):
        raise ValueError(f"{path}: no 'findings' list")
    for e in entries:
        _identity(e)   # KeyError -> malformed entry
    return entries


def save_baseline(path: Path, findings: Sequence[Finding]) -> None:
    entries = sorted(
        ({"check": f.check, "path": f.path, "symbol": f.symbol, "key": f.key}
         for f in findings),
        key=_identity)
    doc = {"version": BASELINE_VERSION, "findings": entries}
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


def diff_baseline(findings: Sequence[Finding], baseline: Sequence[dict],
                  ) -> Tuple[List[Finding], List[Finding], List[dict]]:
    """Split live findings against the baseline multiset.

    Returns ``(new, known, fixed)``: findings absent from the baseline,
    findings it already carries, and baseline entries no longer observed
    (candidates for a baseline refresh).
    """
    budget = Counter(_identity(e) for e in baseline)
    new: List[Finding] = []
    known: List[Finding] = []
    for f in findings:
        if budget[f.identity] > 0:
            budget[f.identity] -= 1
            known.append(f)
        else:
            new.append(f)
    fixed = [dict(zip(("check", "path", "symbol", "key"), ident))
             for ident, count in sorted(budget.items()) for _ in range(count)]
    return new, known, fixed
