"""reprolint runner.

Typical CI usage (exit 0 = no findings beyond the committed baseline,
exit 1 = new findings or a stale baseline path, exit 2 = usage error)::

    python -m tools.reprolint src/ --baseline tools/reprolint/baseline.json \
        --json reprolint-report.json

``--baseline`` defaults to the committed ``tools/reprolint/baseline.json``
when it exists, so ``python -m tools.reprolint src/`` is the full gate.
``--write-baseline`` refreshes the committed file from the current findings
(for intentionally accepted debt — prefer fixing or suppressing inline).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.reprolint.baseline import (diff_baseline, load_baseline,
                                      save_baseline)
from tools.reprolint.checkers import ALL_CHECK_IDS, ALL_CHECKERS
from tools.reprolint.core import Project, run_checkers
from tools.reprolint.reporters import (report_human, report_json, write_json)

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-specific static analysis: dual-path knob parity, "
                    "stats conservation, determinism hazards, Pallas "
                    "kernel contracts")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to scan (default: src)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON; only findings NOT in it fail "
                         "(default: tools/reprolint/baseline.json if "
                         "present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline: every finding fails")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings to the baseline path "
                         "and exit 0")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON report (the CI artifact)")
    ap.add_argument("--checks", default=None,
                    help="comma-separated subset of checks to run")
    ap.add_argument("--list-checks", action="store_true",
                    help="list check ids and exit")
    ap.add_argument("--root", default=".",
                    help="repo root for relative paths and the tests/ "
                         "cross-reference (default: cwd)")
    ap.add_argument("--verbose", action="store_true",
                    help="also print suppressed findings")
    args = ap.parse_args(argv)

    if args.list_checks:
        for checker in ALL_CHECKERS:
            print(f"{checker.name}: {checker.description}")
            for check in checker.checks:
                print(f"  {check}")
        return 0

    only = None
    if args.checks:
        only = {c.strip() for c in args.checks.split(",") if c.strip()}
        unknown = only - set(ALL_CHECK_IDS)
        if unknown:
            ap.error(f"unknown check(s): {', '.join(sorted(unknown))} "
                     f"(see --list-checks)")

    root = Path(args.root).resolve()
    paths = [root / p if not Path(p).is_absolute() else Path(p)
             for p in args.paths]
    missing = [str(p) for p in paths if not p.exists()]
    if missing:
        ap.error(f"no such path(s): {', '.join(missing)}")

    project = Project(root, paths)
    for err in project.errors:
        print(f"skip  unparseable: {err}", file=sys.stderr)
    if not project.files:
        print("FAIL  no Python files found under the given paths "
              "(nothing was checked)", file=sys.stderr)
        return 1

    findings, suppressed = run_checkers(
        project, [cls() for cls in ALL_CHECKERS], only=only)

    baseline_path = None
    if not args.no_baseline:
        if args.baseline is not None:
            baseline_path = Path(args.baseline)
            if not baseline_path.exists() and not args.write_baseline:
                print(f"FAIL  baseline {baseline_path} does not exist "
                      f"(pass --no-baseline to gate on all findings, or "
                      f"--write-baseline to create it)", file=sys.stderr)
                return 1
        elif DEFAULT_BASELINE.exists():
            baseline_path = DEFAULT_BASELINE

    if args.write_baseline:
        target = baseline_path or DEFAULT_BASELINE
        save_baseline(target, findings)
        print(f"wrote {len(findings)} finding(s) to {target}")
        return 0

    baseline = []
    if baseline_path is not None and baseline_path.exists():
        baseline = load_baseline(baseline_path)
    new, _known, fixed = diff_baseline(findings, baseline)

    shown_baseline = str(baseline_path) if baseline_path else None
    report_human(findings, new, suppressed, fixed, shown_baseline,
                 verbose=args.verbose)
    if args.json:
        write_json(report_json(findings, new, suppressed, fixed,
                               [str(p) for p in args.paths],
                               shown_baseline), args.json)
    if new:
        return 1
    print("no new findings vs baseline" if baseline_path
          else "no findings")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
