"""Checker (4): Pallas kernel contracts.

Every kernel in ``kernels/`` ships as a triple — ``<base>_pallas`` (the
kernel), ``ref.<base>_ref`` (the pure-jnp oracle), and an ``ops.<base>``
wrapper dispatching ``pallas``/``interpret``/``xla`` — plus an
interpret-vs-xla test sweep.  CPU CI only ever runs the interpret and xla
legs, so a kernel missing any leg silently loses its correctness coverage.

* ``kernel-ref-parity`` — each ``<base>_pallas`` needs ``<base>_ref`` in
  ``ref.py`` and an ``ops.py`` wrapper ``<base>`` referencing both.
* ``kernel-test-parity`` — some test module must reference the op together
  with the ``interpret`` impl (the cross-backend equivalence sweep).
* ``kernel-grid-guard`` — a ``pallas_call`` grid computed with a plain
  floor division over a dimension, in a function with no ``%`` padding or
  divisibility assert, silently drops the remainder block (severity
  *warning*: it's a heuristic).
* ``kernel-index-map-arity`` — BlockSpec index_map lambdas must take
  exactly ``len(grid)`` arguments (plus one per scalar-prefetch operand
  when a ``PrefetchScalarGridSpec`` carries ``num_scalar_prefetch``).

The kernels package is located structurally: any scanned directory named
``kernels`` containing both ``ops.py`` and ``ref.py``.  Tests are resolved
from ``<repo root>/tests`` (parsed on demand, never linted themselves).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from tools.reprolint.core import Checker, Finding, Project, SourceFile

REF_PARITY = "kernel-ref-parity"
TEST_PARITY = "kernel-test-parity"
GRID_GUARD = "kernel-grid-guard"
INDEX_ARITY = "kernel-index-map-arity"

_SKIP_MODULES = {"__init__.py", "ops.py", "ref.py"}


def _kernels_dirs(project: Project) -> List[str]:
    """Relative dirs named ``kernels`` holding both ops.py and ref.py."""
    dirs: Dict[str, Set[str]] = {}
    for src in project.files:
        rel = Path(src.relpath)
        if rel.parent.name == "kernels":
            dirs.setdefault(rel.parent.as_posix(), set()).add(rel.name)
    return [d for d, names in sorted(dirs.items())
            if {"ops.py", "ref.py"} <= names]


def _top_functions(src: SourceFile) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in src.tree.body
            if isinstance(n, ast.FunctionDef)}


def _names_referenced(fn: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
    return out


class KernelContractChecker(Checker):
    name = "kernel-contracts"
    checks = (REF_PARITY, TEST_PARITY, GRID_GUARD, INDEX_ARITY)
    description = ("every Pallas kernel needs a ref.py twin, an ops.py "
                   "wrapper, an interpret-vs-xla test, and guarded block "
                   "arithmetic")

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for kdir in _kernels_dirs(project):
            findings.extend(self._check_package(project, kdir))
        return findings

    def _check_package(self, project: Project, kdir: str) -> List[Finding]:
        out: List[Finding] = []
        ops = project.file(f"{kdir}/ops.py")
        ref = project.file(f"{kdir}/ref.py")
        kernel_files = [f for f in project.files
                        if Path(f.relpath).parent.as_posix() == kdir
                        and Path(f.relpath).name not in _SKIP_MODULES]
        ref_fns = set(_top_functions(ref)) if ref else set()
        ops_fns = _top_functions(ops) if ops else {}
        tests = project.extra_files("tests")

        for src in kernel_files:
            for name, fn in sorted(_top_functions(src).items()):
                if not name.endswith("_pallas") or name.startswith("_"):
                    continue
                base = name[:-len("_pallas")]
                out.extend(self._check_triple(src, fn, base, name, ref,
                                              ref_fns, ops, ops_fns))
                out.extend(self._check_test(src, fn, base, tests))
            out.extend(self._check_pallas_calls(src))
        return out

    # -- kernel-ref-parity ------------------------------------------------
    def _check_triple(self, src: SourceFile, fn: ast.FunctionDef, base: str,
                      pallas_name: str, ref: Optional[SourceFile],
                      ref_fns: Set[str], ops: Optional[SourceFile],
                      ops_fns: Dict[str, ast.FunctionDef]) -> List[Finding]:
        out: List[Finding] = []

        def add(message: str, key: str):
            out.append(Finding(
                check=REF_PARITY, path=src.relpath, line=fn.lineno,
                symbol=pallas_name, message=message, key=key))

        if f"{base}_ref" not in ref_fns:
            add(f"kernel {pallas_name} has no {base}_ref oracle in ref.py — "
                f"the xla leg of the impl dispatch has nothing to run",
                f"no-ref:{base}")
        wrapper = ops_fns.get(base)
        if wrapper is None:
            add(f"kernel {pallas_name} has no ops.py wrapper `{base}` — "
                f"callers can't dispatch pallas/interpret/xla", f"no-op:{base}")
        else:
            referenced = _names_referenced(wrapper)
            if pallas_name not in referenced:
                add(f"ops.{base} never calls {pallas_name} — the pallas/"
                    f"interpret legs are unwired", f"op-no-pallas:{base}")
            if f"{base}_ref" not in referenced:
                add(f"ops.{base} never calls {base}_ref — the xla leg is "
                    f"unwired", f"op-no-ref:{base}")
        return out

    # -- kernel-test-parity -----------------------------------------------
    def _check_test(self, src: SourceFile, fn: ast.FunctionDef, base: str,
                    tests: List[SourceFile]) -> List[Finding]:
        for tsrc in tests:
            names = _names_referenced(tsrc.tree)
            strings = {n.value for n in ast.walk(tsrc.tree)
                       if isinstance(n, ast.Constant)
                       and isinstance(n.value, str)}
            mentions_op = base in names or f"{base}_pallas" in names
            mentions_interpret = ("interpret" in strings
                                  or "interpret" in names)
            if mentions_op and mentions_interpret:
                return []
        return [Finding(
            check=TEST_PARITY, path=src.relpath, line=fn.lineno,
            symbol=f"{base}_pallas",
            message=(f"no test references `{base}` together with the "
                     f"'interpret' impl — the interpret-vs-xla equivalence "
                     f"sweep doesn't cover this kernel"),
            key=f"untested:{base}")]

    # -- grid guards and index-map arity ----------------------------------
    def _check_pallas_calls(self, src: SourceFile) -> List[Finding]:
        out: List[Finding] = []
        for fn in [n for n in ast.walk(src.tree)
                   if isinstance(n, ast.FunctionDef)]:
            calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)
                     and self._callee_name(n) in ("pallas_call",
                                                  "PrefetchScalarGridSpec")]
            if not calls:
                continue
            grid, prefetch = self._grid_of(fn)
            has_mod = any(isinstance(n, ast.BinOp)
                          and isinstance(n.op, ast.Mod)
                          for n in ast.walk(fn))
            if grid is not None and not has_mod:
                for elt in grid.elts:
                    if self._is_bare_floordiv(elt, fn):
                        out.append(Finding(
                            check=GRID_GUARD, path=src.relpath,
                            line=elt.lineno, symbol=fn.name,
                            message=("grid dimension computed by floor "
                                     "division with no % padding or "
                                     "divisibility assert in scope — the "
                                     "remainder block is silently dropped"),
                            key="unguarded-floordiv",
                            severity="warning"))
            if grid is not None:
                expected = len(grid.elts) + prefetch
                for lam in [n for n in ast.walk(fn)
                            if isinstance(n, ast.Lambda)]:
                    arity = len(lam.args.args)
                    if arity != expected:
                        out.append(Finding(
                            check=INDEX_ARITY, path=src.relpath,
                            line=lam.lineno, symbol=fn.name,
                            message=(f"index_map lambda takes {arity} "
                                     f"args but the grid has "
                                     f"{len(grid.elts)} dims"
                                     + (f" + {prefetch} scalar-prefetch "
                                        f"operand(s)" if prefetch else "")
                                     + " — block indexing is misaligned"),
                            key=f"arity:{arity}-vs-{expected}"))
        return out

    @staticmethod
    def _callee_name(call: ast.Call) -> Optional[str]:
        if isinstance(call.func, ast.Name):
            return call.func.id
        if isinstance(call.func, ast.Attribute):
            return call.func.attr
        return None

    def _grid_of(self, fn: ast.FunctionDef) -> Tuple[Optional[ast.Tuple],
                                                     int]:
        """The literal ``grid=`` tuple used by this function's pallas_call
        (directly or via a PrefetchScalarGridSpec) and the scalar-prefetch
        count."""
        grid: Optional[ast.Tuple] = None
        prefetch = 0
        for call in [n for n in ast.walk(fn) if isinstance(n, ast.Call)]:
            name = self._callee_name(call)
            if name not in ("pallas_call", "PrefetchScalarGridSpec"):
                continue
            for kw in call.keywords:
                if kw.arg == "grid" and isinstance(kw.value, ast.Tuple):
                    grid = kw.value
                if kw.arg == "num_scalar_prefetch" \
                        and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, int):
                    prefetch = kw.value.value
        return grid, prefetch

    def _is_bare_floordiv(self, elt: ast.AST, fn: ast.FunctionDef) -> bool:
        """True when the grid element is (or is assigned from) a plain
        ``a // b`` floor division."""
        if isinstance(elt, ast.BinOp) and isinstance(elt.op, ast.FloorDiv):
            return True
        if isinstance(elt, ast.Name):
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == elt.id
                        and isinstance(node.value, ast.BinOp)
                        and isinstance(node.value.op, ast.FloorDiv)):
                    return True
        return False
