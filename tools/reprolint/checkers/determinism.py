"""Checker (3): determinism hazards in the serving layer.

The whole test strategy (vec-vs-ref bit-identity, golden stats rows,
tracer=None non-perturbation) assumes a run is a pure function of
``(trace, seed, knobs)``.  Anything that lets iteration order, object
identity, process state, or the wall clock leak into a scheduling decision
breaks that silently — usually only under a different hash seed or Python
version, i.e. in someone else's CI.  Scoped to paths containing a
``serving`` component.

* ``set-iteration-order`` — ``for``/comprehension iteration over a set
  literal, set comprehension, or direct ``set()``/``frozenset()`` call.
  Membership tests and ``sorted(set(...))`` are fine; bare iteration order
  is hash-seed-dependent.
* ``id-identity`` — any ``id()`` call: object identity as a sort key or
  tie-break differs run to run.
* ``unseeded-rng`` — module-level ``np.random.*`` / ``random.*`` draws and
  ``default_rng()`` without a seed; all randomness must flow from an
  explicit seed threaded through the config.
* ``wall-clock`` — ``time.time``/``monotonic``/``perf_counter`` and
  ``datetime.now``-family reads; simulation time is ``engine.t``, never the
  host clock.
* ``eager-knob-validation`` — a class with a knob field whose legal values
  live in a module-level registry tuple (``order``/``ORDERINGS``,
  ``reserve``/``RESERVES``, ...) must validate membership in
  ``__init__``/``__post_init__`` instead of failing deep in dispatch (or
  silently falling through, as ``Policy.reserve`` once did).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.reprolint.core import Checker, Finding, Project, SourceFile

SET_ITER = "set-iteration-order"
ID_IDENTITY = "id-identity"
UNSEEDED = "unseeded-rng"
WALL_CLOCK = "wall-clock"
EAGER = "eager-knob-validation"

# knob field name -> module-level registry constant of its legal values
KNOB_REGISTRIES = {
    "order": "ORDERINGS",
    "reserve": "RESERVES",
    "preempt_mode": "PREEMPT_MODES",
    "chunk_order": "CHUNK_ORDERS",
    "router": "ROUTERS",
    "steal": "STEAL_MODES",
}

# module-level RNG draws on numpy's global state
_NP_GLOBAL_RNG = {
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "normal", "uniform", "exponential", "poisson",
    "beta", "gamma", "seed",
}
_PY_RANDOM = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "seed",
}
_WALL_CLOCK_CHAINS = {
    ("time", "time"), ("time", "time_ns"), ("time", "monotonic"),
    ("time", "monotonic_ns"), ("time", "perf_counter"),
    ("time", "perf_counter_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("datetime", "datetime", "now"), ("datetime", "datetime", "utcnow"),
    ("datetime", "datetime", "today"), ("datetime", "date", "today"),
}


def _chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_setish(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


class DeterminismChecker(Checker):
    name = "determinism"
    checks = (SET_ITER, ID_IDENTITY, UNSEEDED, WALL_CLOCK, EAGER)
    description = ("no set-order, object-identity, global-RNG, or "
                   "wall-clock dependence in scheduling decisions")

    # paths must contain this component to be in scope (the serving layer
    # is where nondeterminism corrupts the science; kernels/training have
    # their own seeding conventions)
    scope_component = "serving"

    def in_scope(self, src: SourceFile) -> bool:
        return self.scope_component in src.relpath.split("/")

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for src in project.files:
            if not self.in_scope(src):
                continue
            findings.extend(self._check_hazards(src))
            findings.extend(self._check_eager_validation(src))
        return findings

    # -- syntactic hazards ------------------------------------------------
    def _check_hazards(self, src: SourceFile) -> List[Finding]:
        out: List[Finding] = []

        def add(check: str, node: ast.AST, message: str, key: str):
            out.append(Finding(
                check=check, path=src.relpath, line=node.lineno,
                symbol=src.symbol_at(node.lineno), message=message, key=key))

        for node in ast.walk(src.tree):
            iters: List[ast.AST] = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend(g.iter for g in node.generators)
            for it in iters:
                if _is_setish(it):
                    add(SET_ITER, it,
                        "iteration over an unordered set — order is "
                        "hash-seed-dependent; sort it (or iterate the "
                        "ordered source)", "set-iteration")

            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) and node.func.id == "id" \
                    and len(node.args) == 1:
                add(ID_IDENTITY, node,
                    "id() leaks object identity into the computation — "
                    "identity differs run to run; key on a stable field "
                    "(rid, seq counter)", "id-call")
            chain = _chain(node.func)
            if chain is None:
                continue
            if (len(chain) == 3 and chain[0] in ("np", "numpy")
                    and chain[1] == "random"
                    and chain[2] in _NP_GLOBAL_RNG):
                add(UNSEEDED, node,
                    f"np.random.{chain[2]} draws from the global RNG — "
                    f"use a seeded np.random.default_rng(seed)",
                    f"np-global:{chain[2]}")
            if chain[-1] == "default_rng" and not node.args \
                    and not node.keywords:
                add(UNSEEDED, node,
                    "default_rng() without a seed — thread the config seed "
                    "through", "default-rng-unseeded")
            if len(chain) == 2 and chain[0] == "random" \
                    and chain[1] in _PY_RANDOM:
                add(UNSEEDED, node,
                    f"random.{chain[1]} draws from the process-global RNG — "
                    f"use a seeded generator", f"py-global:{chain[1]}")
            if chain in _WALL_CLOCK_CHAINS:
                add(WALL_CLOCK, node,
                    f"wall-clock read {'.'.join(chain)}() — simulation time "
                    f"is engine.t; host time makes runs irreproducible",
                    f"clock:{'.'.join(chain)}")
        return out

    # -- eager-knob-validation -------------------------------------------
    def _check_eager_validation(self, src: SourceFile) -> List[Finding]:
        module_consts = {
            t.id for n in src.tree.body if isinstance(n, ast.Assign)
            for t in n.targets if isinstance(t, ast.Name)
        }
        out: List[Finding] = []
        for cls in [n for n in ast.walk(src.tree)
                    if isinstance(n, ast.ClassDef)]:
            if cls.name.endswith("Stats"):
                # stats records echo knobs for provenance (router, policy);
                # the validating owner is the class that *consumes* the knob
                continue
            knobs = self._class_knobs(cls)
            if not knobs:
                continue
            validated = self._validated_registries(cls)
            for fname, lineno in sorted(knobs.items(), key=lambda kv: kv[1]):
                registry = KNOB_REGISTRIES[fname]
                if registry not in module_consts:
                    continue       # values live elsewhere; out of scope
                if registry in validated:
                    continue
                out.append(Finding(
                    check=EAGER, path=src.relpath, line=lineno,
                    symbol=cls.name,
                    message=(f"{cls.name}.{fname} is never validated "
                             f"against {registry} in __init__/"
                             f"__post_init__ — an unknown value fails deep "
                             f"in dispatch (or silently misbehaves)"),
                    key=f"unvalidated:{fname}"))
        return out

    @staticmethod
    def _class_knobs(cls: ast.ClassDef) -> Dict[str, int]:
        """Knob fields of the class: annotated dataclass fields and
        __init__ parameters whose name appears in KNOB_REGISTRIES."""
        knobs: Dict[str, int] = {}
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name) \
                    and stmt.target.id in KNOB_REGISTRIES:
                knobs[stmt.target.id] = stmt.lineno
        init = next((n for n in cls.body if isinstance(n, ast.FunctionDef)
                     and n.name == "__init__"), None)
        if init is not None:
            for arg in init.args.args + init.args.kwonlyargs:
                if arg.arg in KNOB_REGISTRIES and arg.arg not in knobs:
                    knobs[arg.arg] = arg.lineno
        return knobs

    @staticmethod
    def _validated_registries(cls: ast.ClassDef) -> Set[str]:
        """Registry constants membership-tested inside __init__ or
        __post_init__."""
        validated: Set[str] = set()
        for meth in cls.body:
            if not (isinstance(meth, ast.FunctionDef)
                    and meth.name in ("__init__", "__post_init__")):
                continue
            for node in ast.walk(meth):
                if not isinstance(node, ast.Compare):
                    continue
                if not any(isinstance(op, (ast.In, ast.NotIn))
                           for op in node.ops):
                    continue
                for comp in node.comparators:
                    if isinstance(comp, ast.Name):
                        validated.add(comp.id)
        return validated
