"""Checker registry — one module per checker family."""

from tools.reprolint.checkers.conservation import ConservationChecker
from tools.reprolint.checkers.determinism import DeterminismChecker
from tools.reprolint.checkers.dual_path import DualPathChecker
from tools.reprolint.checkers.kernel_contracts import KernelContractChecker

ALL_CHECKERS = (
    DualPathChecker,
    ConservationChecker,
    DeterminismChecker,
    KernelContractChecker,
)

ALL_CHECK_IDS = tuple(sorted(
    check for checker in ALL_CHECKERS for check in checker.checks))
