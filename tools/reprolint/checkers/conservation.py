"""Checker (2): stats conservation and the tracer kind registry.

The serving telemetry conserves requests (``submitted == finish + timeout +
rejected + dropped``) only if every layer forwards every counter.  Three
mechanical invariants keep that true as counters accrete:

* ``stats-cluster-parity`` — every ``ServeStats`` field must have a
  same-named ``ClusterStats`` field, else a per-replica counter silently
  vanishes at the cluster merge (how ``held_releases``/``prefix_evictions``
  went missing; fixed in the PR that introduced this checker).  Genuinely
  per-replica fields (``page_size`` on a heterogeneous fleet) carry an
  inline suppression.
* ``stats-merge-aggregation`` — every *int-annotated* (counter) field of
  ``ServeStats``/``ClusterStats`` must be passed as an explicit keyword in
  the constructor call inside ``SimEngine.stats`` / ``Cluster._stats``; a
  field added with a default but never filled reports zero forever.
  (Float summary fields arrive via ``**latency_summary(...)``-style
  expansions the AST can't see through, so they are out of scope here.)
* ``stats-exporter-surfacing`` — ``row()`` must surface every field to the
  JSON/Prometheus exporters: a ``self.__dict__.copy()`` body surfaces all,
  each ``.pop("x")`` hides one (finding unless suppressed), a dict-literal
  body surfaces exactly its keys.

* ``tracer-kind-registry`` — every constant event kind passed to
  ``*.emit(t, replica, rid, kind, ...)`` must be declared in
  ``EVENT_KINDS``, every declared kind must be emitted somewhere, and
  ``TERMINAL_KINDS`` must be a subset of the registry.  An undeclared kind
  bypasses the conservation accounting in ``Tracer.terminal_counts``; a
  never-emitted kind is a dead registry entry that masks typos.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.reprolint.core import (Checker, Finding, Project, SourceFile,
                                  const_str, dataclass_fields, str_tuple)

PARITY = "stats-cluster-parity"
MERGE = "stats-merge-aggregation"
SURFACE = "stats-exporter-surfacing"
KINDS = "tracer-kind-registry"

# (per-replica class, merged class, merge method owner, merge method)
STATS_PAIR = ("ServeStats", "ClusterStats")
MERGE_SITES = {"ServeStats": ("SimEngine", "stats"),
               "ClusterStats": ("Cluster", "_stats")}


def _find_class(project: Project, name: str,
                ) -> Optional[Tuple[SourceFile, ast.ClassDef]]:
    for src in project.files:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef) and node.name == name:
                return src, node
    return None


def _find_method(project: Project, cls_name: str, meth: str,
                 ) -> Optional[Tuple[SourceFile, ast.FunctionDef]]:
    hit = _find_class(project, cls_name)
    if hit is None:
        return None
    src, cls = hit
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == meth:
            return src, node
    return None


class ConservationChecker(Checker):
    name = "conservation"
    checks = (PARITY, MERGE, SURFACE, KINDS)
    description = ("counters must survive the cluster merge and reach the "
                   "exporters; tracer kinds must match the registry")

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        findings.extend(self._check_field_parity(project))
        findings.extend(self._check_merge(project))
        findings.extend(self._check_row_surfacing(project))
        findings.extend(self._check_kinds(project))
        return findings

    # -- stats-cluster-parity --------------------------------------------
    def _check_field_parity(self, project: Project) -> List[Finding]:
        serve = _find_class(project, STATS_PAIR[0])
        cluster = _find_class(project, STATS_PAIR[1])
        if serve is None or cluster is None:
            return []
        src, cls = serve
        cluster_fields = {n for n, _, _ in dataclass_fields(cluster[1])}
        out = []
        for fname, lineno, _ in dataclass_fields(cls):
            if fname not in cluster_fields:
                out.append(Finding(
                    check=PARITY, path=src.relpath, line=lineno,
                    symbol=cls.name,
                    message=(f"ServeStats.{fname} has no ClusterStats "
                             f"counterpart — the counter vanishes at the "
                             f"cluster merge (aggregate it, or suppress if "
                             f"genuinely per-replica)"),
                    key=f"unmerged-field:{fname}"))
        return out

    # -- stats-merge-aggregation -----------------------------------------
    def _check_merge(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for stats_cls, (owner, meth) in MERGE_SITES.items():
            target = _find_class(project, stats_cls)
            site = _find_method(project, owner, meth)
            if target is None or site is None:
                continue
            src, fn = site
            call = self._constructor_call(fn, stats_cls)
            if call is None:
                out.append(Finding(
                    check=MERGE, path=src.relpath, line=fn.lineno,
                    symbol=f"{owner}.{meth}",
                    message=(f"{owner}.{meth} never constructs {stats_cls} "
                             f"— the merge site the checker audits is gone"),
                    key=f"no-constructor:{stats_cls}"))
                continue
            passed = {kw.arg for kw in call.keywords if kw.arg is not None}
            for fname, _, ann in dataclass_fields(target[1]):
                if ann != "int" or fname in passed:
                    continue
                out.append(Finding(
                    check=MERGE, path=src.relpath, line=call.lineno,
                    symbol=f"{owner}.{meth}",
                    message=(f"counter {stats_cls}.{fname} is not passed in "
                             f"the {stats_cls}(...) call — it will report "
                             f"its default forever"),
                    key=f"unaggregated:{stats_cls}.{fname}"))
        return out

    @staticmethod
    def _constructor_call(fn: ast.FunctionDef, cls_name: str,
                          ) -> Optional[ast.Call]:
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                    and node.func.id == cls_name):
                return node
        return None

    # -- stats-exporter-surfacing ----------------------------------------
    def _check_row_surfacing(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for stats_cls in STATS_PAIR:
            hit = _find_class(project, stats_cls)
            if hit is None:
                continue
            src, cls = hit
            fields = {n for n, _, _ in dataclass_fields(cls)}
            row = next((n for n in cls.body
                        if isinstance(n, ast.FunctionDef)
                        and n.name == "row"), None)
            if row is None:
                out.append(Finding(
                    check=SURFACE, path=src.relpath, line=cls.lineno,
                    symbol=stats_cls,
                    message=f"{stats_cls} has no row() exporter method",
                    key="no-row"))
                continue
            surfaced, hidden = self._row_coverage(row, fields)
            for fname, lineno in sorted(hidden.items()):
                out.append(Finding(
                    check=SURFACE, path=src.relpath, line=lineno,
                    symbol=f"{stats_cls}.row",
                    message=(f"{stats_cls}.{fname} is dropped from row() — "
                             f"it never reaches the JSON/Prometheus "
                             f"exporters"),
                    key=f"unsurfaced:{fname}"))
            if surfaced is not None:
                for fname in sorted(fields - surfaced - set(hidden)):
                    out.append(Finding(
                        check=SURFACE, path=src.relpath, line=row.lineno,
                        symbol=f"{stats_cls}.row",
                        message=(f"{stats_cls}.{fname} is missing from the "
                                 f"dict row() returns"),
                        key=f"unsurfaced:{fname}"))
        return out

    @staticmethod
    def _row_coverage(row: ast.FunctionDef, fields: Set[str],
                      ) -> Tuple[Optional[Set[str]], Dict[str, int]]:
        """(surfaced keys or None for __dict__-based "all", hidden
        field -> pop lineno)."""
        hidden: Dict[str, int] = {}
        dict_based = False
        literal_keys: Optional[Set[str]] = None
        for node in ast.walk(row):
            if (isinstance(node, ast.Attribute)
                    and node.attr == "__dict__"):
                dict_based = True
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "pop" and node.args):
                key = const_str(node.args[0])
                if key is not None and key in fields:
                    hidden[key] = node.lineno
            if isinstance(node, ast.Return) and isinstance(node.value,
                                                           ast.Dict):
                literal_keys = {const_str(k) for k in node.value.keys
                                if k is not None and const_str(k)}
        if dict_based:
            return None, hidden
        return literal_keys or set(), hidden

    # -- tracer-kind-registry --------------------------------------------
    def _check_kinds(self, project: Project) -> List[Finding]:
        registry = self._registry(project, "EVENT_KINDS")
        if registry is None:
            return []
        reg_src, reg_line, kinds = registry
        out: List[Finding] = []
        emitted: Dict[str, Tuple[str, int]] = {}
        for src in project.files:
            for node in ast.walk(src.tree):
                kind = self._emit_kind(node)
                if kind is None:
                    continue
                emitted.setdefault(kind, (src.relpath, node.lineno))
                if kind not in kinds:
                    out.append(Finding(
                        check=KINDS, path=src.relpath, line=node.lineno,
                        symbol=src.symbol_at(node.lineno),
                        message=(f"event kind '{kind}' is emitted but not "
                                 f"declared in EVENT_KINDS — it bypasses "
                                 f"the conservation accounting"),
                        key=f"unregistered:{kind}"))
        for kind in kinds:
            if kind not in emitted:
                out.append(Finding(
                    check=KINDS, path=reg_src.relpath, line=reg_line,
                    symbol="<module>",
                    message=(f"EVENT_KINDS declares '{kind}' but no emit "
                             f"site produces it — dead registry entry"),
                    key=f"unemitted:{kind}"))
        terminal = self._registry(project, "TERMINAL_KINDS")
        if terminal is not None:
            t_src, t_line, t_kinds = terminal
            for kind in t_kinds:
                if kind not in kinds:
                    out.append(Finding(
                        check=KINDS, path=t_src.relpath, line=t_line,
                        symbol="<module>",
                        message=(f"TERMINAL_KINDS member '{kind}' is not in "
                                 f"EVENT_KINDS"),
                        key=f"terminal-unregistered:{kind}"))
        return out

    @staticmethod
    def _registry(project: Project, const: str,
                  ) -> Optional[Tuple[SourceFile, int, Tuple[str, ...]]]:
        for src in project.files:
            for node in src.tree.body:
                if (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == const):
                    kinds = str_tuple(node.value)
                    if kinds is not None:
                        return src, node.lineno, kinds
        return None

    @staticmethod
    def _emit_kind(node: ast.AST) -> Optional[str]:
        """Constant kind of a ``<anything>.emit(t, replica, rid, kind, …)``
        call, else None."""
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "emit" and len(node.args) >= 4):
            return None
        return const_str(node.args[3])
