"""Checker (1): dual-path knob parity — the #1 historical bug source.

``SimEngine`` maintains two implementations of the same decode semantics:
the per-slot reference loop (``_decode_tick_ref`` / ``_decode_tick_budget``)
and the vectorized/event-leap machinery (``_decode_tick_vec`` /
``ticks_to_event`` / ``leap``), which must stay bit-exact.  Every PR that
adds a ``Policy``/``ReplicaSpec`` knob must thread it through BOTH; the
unthreaded-knob class of bug (a knob consulted on one path only) is exactly
what broke ``fit_page_size`` stealing and the early chunked-prefill
feasibility logic.

The checker compares the *knob-read sets* of the two paths in any class
that defines at least one root method of each side:

* group "tick" — the sibling plain-decode implementations
  ``_decode_tick_ref`` vs ``_decode_tick_vec``, own bodies only: these are
  two spellings of one tick and must consult identical knobs;
* group "path" — the whole reference side vs the whole vectorized/leap
  side, each taken with its *exclusive* call closure (helpers also
  reachable from common code like ``_admit``/``step`` are shared semantics
  and excluded, as are the other side's roots — the vec tick's fallback
  into the reference tick doesn't grant it the reference reads).

A "knob read" is an attribute read rooted at ``self.policy`` / ``self.spec``
(or a local alias of either), plus reads of *derived knobs*: ``self._x``
attributes assigned in ``__init__``/``reset`` from a pure expression over
policy/spec fields (``self._budget = spec.step_token_budget`` makes a
``self._budget`` read count as reading ``spec.step_token_budget``).

A knob read on one side and never on the other is a finding listing every
read site; suppressing any one site (``# reprolint:
disable=dual-path-knob-parity -- why``) acknowledges the asymmetry.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from tools.reprolint.core import Checker, Finding, Project, SourceFile

CHECK = "dual-path-knob-parity"

KNOB_ROOTS = ("policy", "spec")

# calls considered pure enough for derived-knob extraction; anything else
# (constructors, methods) makes the assignment opaque and it is skipped
_PURE_CALLS = {"int", "float", "bool", "abs", "min", "max", "tuple", "list",
               "round"}


@dataclass(frozen=True)
class PathGroup:
    group: str
    side_a: str
    roots_a: Tuple[str, ...]
    side_b: str
    roots_b: Tuple[str, ...]
    closure: bool     # include each side's *exclusive* call closure


GROUPS = (
    PathGroup("tick", "reference tick", ("_decode_tick_ref",),
              "vectorized tick", ("_decode_tick_vec",), closure=False),
    PathGroup("path", "reference path",
              ("_decode_tick_ref", "_decode_tick_budget"),
              "vectorized/leap path",
              ("_decode_tick_vec", "ticks_to_event", "leap",
               "_budget_constrained"),
              closure=True),
)

# (method, lineno) read sites per knob
KnobSites = Dict[str, List[Tuple[str, int]]]


def _method_map(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _knob_refs_in_expr(expr: ast.AST, derived: Dict[str, Set[str]],
                       ) -> Optional[Set[str]]:
    """Knobs referenced by a pure expression; None if the expression is
    opaque (calls anything beyond builtin coercions)."""
    knobs: Set[str] = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            if not (isinstance(node.func, ast.Name)
                    and node.func.id in _PURE_CALLS):
                return None
        if isinstance(node, ast.Attribute):
            base = node.value
            # self.policy.F / self.spec.F
            if (isinstance(base, ast.Attribute)
                    and isinstance(base.value, ast.Name)
                    and base.value.id == "self"
                    and base.attr in KNOB_ROOTS):
                knobs.add(f"{base.attr}.{node.attr}")
            # policy.F / spec.F (ctor params)
            elif isinstance(base, ast.Name) and base.id in KNOB_ROOTS:
                knobs.add(f"{base.id}.{node.attr}")
            # self._derived
            elif (isinstance(base, ast.Name) and base.id == "self"
                    and node.attr in derived):
                knobs |= derived[node.attr]
    return knobs


def _derived_knobs(methods: Dict[str, ast.FunctionDef]) -> Dict[str, Set[str]]:
    """``self.<name> -> {knob, ...}`` for attributes assigned in
    ``__init__``/``reset`` from pure expressions over policy/spec fields."""
    derived: Dict[str, Set[str]] = {}
    bodies = [methods[m] for m in ("__init__", "reset") if m in methods]
    for _ in range(2):   # second pass resolves derived-of-derived
        for fn in bodies:
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1):
                    continue
                tgt = node.targets[0]
                if not (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    continue
                knobs = _knob_refs_in_expr(node.value, derived)
                if knobs:
                    derived.setdefault(tgt.attr, set()).update(knobs)
    return derived


def _local_aliases(fn: ast.FunctionDef) -> Dict[str, str]:
    """Locals assigned directly from ``self.policy``/``self.spec``."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Attribute)
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id == "self"
                and node.value.attr in KNOB_ROOTS):
            aliases[node.targets[0].id] = node.value.attr
    return aliases


def _knob_reads(fn: ast.FunctionDef, derived: Dict[str, Set[str]],
                ) -> List[Tuple[str, int]]:
    """Every (knob, lineno) read inside ``fn`` (Store/Del contexts are
    writes, not reads)."""
    aliases = _local_aliases(fn)
    reads: List[Tuple[str, int]] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Attribute):
            continue
        if isinstance(node.ctx, (ast.Store, ast.Del)):
            continue
        base = node.value
        if (isinstance(base, ast.Attribute)
                and isinstance(base.value, ast.Name)
                and base.value.id == "self" and base.attr in KNOB_ROOTS):
            reads.append((f"{base.attr}.{node.attr}", node.lineno))
        elif isinstance(base, ast.Name) and base.id in aliases:
            reads.append((f"{aliases[base.id]}.{node.attr}", node.lineno))
        elif (isinstance(base, ast.Name) and base.id == "self"
                and node.attr in derived):
            for knob in sorted(derived[node.attr]):
                reads.append((knob, node.lineno))
    return reads


def _call_graph(methods: Dict[str, ast.FunctionDef]) -> Dict[str, Set[str]]:
    graph: Dict[str, Set[str]] = {}
    for name, fn in methods.items():
        callees: Set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"
                    and node.func.attr in methods):
                callees.add(node.func.attr)
        graph[name] = callees
    return graph


def _reach(graph: Dict[str, Set[str]], roots: Tuple[str, ...],
           stop: Set[str]) -> Set[str]:
    seen: Set[str] = set()
    frontier = [r for r in roots if r in graph]
    while frontier:
        m = frontier.pop()
        if m in seen:
            continue
        seen.add(m)
        for callee in graph.get(m, ()):
            if callee not in stop and callee not in seen:
                frontier.append(callee)
    return seen


def _side_methods(group: PathGroup, side_roots: Tuple[str, ...],
                  other_roots: Tuple[str, ...],
                  methods: Dict[str, ast.FunctionDef]) -> Set[str]:
    present = tuple(r for r in side_roots if r in methods)
    if not group.closure:
        return set(present)
    graph = _call_graph(methods)
    all_roots = set(group.roots_a) | set(group.roots_b)
    reach_side = _reach(graph, present, stop=set(other_roots))
    # common code: anything reachable from a method that belongs to neither
    # side — its knob reads are shared semantics, not path-specific ones
    reach_a = _reach(graph, group.roots_a, stop=set(group.roots_b))
    reach_b = _reach(graph, group.roots_b, stop=set(group.roots_a))
    common_starts = tuple(m for m in methods
                          if m not in (reach_a | reach_b | all_roots))
    reach_common = _reach(graph, common_starts, stop=all_roots)
    return (reach_side | set(present)) - reach_common


def _collect_side(group: PathGroup, side_roots: Tuple[str, ...],
                  other_roots: Tuple[str, ...],
                  methods: Dict[str, ast.FunctionDef],
                  derived: Dict[str, Set[str]]) -> KnobSites:
    sites: KnobSites = {}
    for name in sorted(_side_methods(group, side_roots, other_roots,
                                     methods)):
        for knob, line in _knob_reads(methods[name], derived):
            sites.setdefault(knob, []).append((name, line))
    return sites


class DualPathChecker(Checker):
    name = "dual-path"
    checks = (CHECK,)
    description = ("Policy/ReplicaSpec knobs must be read on both the "
                   "reference and the vectorized/event-leap decode path")

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for src in project.files:
            for cls in [n for n in ast.walk(src.tree)
                        if isinstance(n, ast.ClassDef)]:
                findings.extend(self._check_class(src, cls))
        return findings

    def _check_class(self, src: SourceFile, cls: ast.ClassDef,
                     ) -> List[Finding]:
        methods = _method_map(cls)
        findings: List[Finding] = []
        derived = _derived_knobs(methods)
        for group in GROUPS:
            if not (set(group.roots_a) & set(methods)
                    and set(group.roots_b) & set(methods)):
                continue
            sites_a = _collect_side(group, group.roots_a, group.roots_b,
                                    methods, derived)
            sites_b = _collect_side(group, group.roots_b, group.roots_a,
                                    methods, derived)
            for knob in sorted(set(sites_a) - set(sites_b)):
                findings.append(self._finding(src, cls, group, knob,
                                              sites_a[knob], group.side_a,
                                              group.side_b))
            for knob in sorted(set(sites_b) - set(sites_a)):
                findings.append(self._finding(src, cls, group, knob,
                                              sites_b[knob], group.side_b,
                                              group.side_a))
        return findings

    @staticmethod
    def _finding(src: SourceFile, cls: ast.ClassDef, group: PathGroup,
                 knob: str, sites: List[Tuple[str, int]], read_side: str,
                 missing_side: str) -> Finding:
        where = ", ".join(f"{m}:{ln}" for m, ln in sites)
        lines = tuple(ln for _, ln in sites)
        return Finding(
            check=CHECK,
            path=src.relpath,
            line=lines[0],
            symbol=f"{cls.name}.{sites[0][0]}",
            message=(f"knob `{knob}` is read on the {read_side} ({where}) "
                     f"but never on the {missing_side} — thread it through "
                     f"both or suppress one read site with a justification"),
            key=f"{group.group}:{knob}:unread-on:{missing_side}",
            extra_lines=lines[1:],
        )
