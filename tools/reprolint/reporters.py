"""Human and JSON reporters for reprolint runs.

The human reporter mirrors ``benchmarks/check_regression.py``: one line per
item, a one-line tally, and ``FAIL`` lines on stderr for whatever gates the
exit code (here: findings new vs. the baseline).  The JSON report is the CI
artifact; its schema is pinned by ``tests/test_reprolint.py``.
"""

from __future__ import annotations

import json
import sys
from typing import List, Optional, Sequence, TextIO

from tools.reprolint.core import Finding

JSON_SCHEMA_VERSION = 1


def report_human(findings: Sequence[Finding], new: Sequence[Finding],
                 suppressed: Sequence[Finding], fixed: Sequence[dict],
                 baseline_path: Optional[str], verbose: bool = False,
                 out: Optional[TextIO] = None,
                 err: Optional[TextIO] = None) -> None:
    # late-bound so stream redirection (pytest capture, CI tee) is honoured
    out = sys.stdout if out is None else out
    err = sys.stderr if err is None else err
    new_ids = {id(f) for f in new}
    for f in findings:
        tag = "NEW " if id(f) in new_ids else "base"
        print(f"{tag}  {f.path}:{f.line}: [{f.check}] {f.message}", file=out)
    if verbose:
        for f in suppressed:
            print(f"supp  {f.path}:{f.line}: [{f.check}] {f.message}",
                  file=out)
    for e in fixed:
        print(f"gone  {e['path']}: [{e['check']}] {e['key']} "
              f"(baselined but no longer observed — refresh the baseline)",
              file=out)
    vs = f" vs baseline {baseline_path}" if baseline_path else " (no baseline)"
    print(f"{len(findings)} finding(s), {len(suppressed)} suppressed, "
          f"{len(new)} new{vs}", file=out)
    for f in new:
        print(f"FAIL  {f.path}:{f.line}: [{f.check}] {f.message}", file=err)


def report_json(findings: Sequence[Finding], new: Sequence[Finding],
                suppressed: Sequence[Finding], fixed: Sequence[dict],
                paths: Sequence[str], baseline_path: Optional[str]) -> dict:
    new_ids = {id(f) for f in new}

    def encode(f: Finding) -> dict:
        d = f.to_dict()
        d["new"] = id(f) in new_ids
        return d

    return {
        "version": JSON_SCHEMA_VERSION,
        "tool": "reprolint",
        "paths": list(paths),
        "baseline": baseline_path,
        "counts": {
            "findings": len(findings),
            "new": len(new),
            "suppressed": len(suppressed),
            "fixed": len(fixed),
        },
        "findings": [encode(f) for f in findings],
        "suppressed": [f.to_dict() for f in suppressed],
        "fixed": list(fixed),
    }


def write_json(doc: dict, path: str) -> None:
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
