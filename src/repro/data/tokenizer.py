"""Toy tokenizer + synthetic corpus for the Track-B end-to-end demo.

A tiny "language" whose ground-truth generation-length law is heavy-tailed and
topic-conditioned: a prompt is [BOS, topic, style...] and the continuation
length is drawn from a topic-conditional lognormal+Pareto mixture, terminated
by EOS. A tiny LM trained on this corpus learns a stochastic EOS hazard, so
sampling it at temperature 0.8 genuinely reproduces the paper's Observation 1/2
phenomenology — real repeated generations with prompt-conditioned spread.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_TOPICS = 8
TOPIC0 = 3                      # topic token ids: TOPIC0 .. TOPIC0+N_TOPICS-1
CONTENT0 = 3 + N_TOPICS         # content tokens start here
VOCAB = 512

# topic -> (median length, body sigma, tail weight, tail alpha)
TOPIC_LAWS = [
    (12, 0.25, 0.03, 2.5), (18, 0.30, 0.04, 2.2), (26, 0.35, 0.05, 2.0),
    (36, 0.30, 0.05, 2.0), (48, 0.40, 0.06, 1.9), (64, 0.35, 0.05, 2.1),
    (20, 0.55, 0.08, 1.8), (40, 0.60, 0.08, 1.8),
]


@dataclass(frozen=True)
class ToyTokenizer:
    vocab_size: int = VOCAB

    def prompt(self, rng: np.random.Generator, topic: int, n_style: int = 4) -> np.ndarray:
        style = rng.integers(CONTENT0, CONTENT0 + 64, size=n_style)
        return np.concatenate([[BOS, TOPIC0 + topic], style]).astype(np.int32)


def sample_continuation_length(rng: np.random.Generator, topic: int,
                               max_len: int = 240) -> int:
    m, sigma, w, alpha = TOPIC_LAWS[topic]
    if rng.random() < w:
        L = m * rng.random() ** (-1.0 / alpha)
    else:
        L = m * np.exp(sigma * rng.standard_normal())
    return int(np.clip(np.rint(L), 2, max_len))


def make_sequence(rng: np.random.Generator, topic: int, seq_len: int,
                  max_gen: int = 240) -> Tuple[np.ndarray, np.ndarray, int]:
    """One training sequence: prompt + content + EOS, padded to seq_len.

    Returns (tokens (seq_len,), loss_mask (seq_len,), true_length)."""
    tok = ToyTokenizer()
    prompt = tok.prompt(rng, topic)
    L = sample_continuation_length(rng, topic, max_gen)
    # content distribution is topic-specific so the LM can also learn topicality
    lo = CONTENT0 + 64 + topic * 48
    content = rng.integers(lo, lo + 48, size=L)
    seq = np.concatenate([prompt, content, [EOS]])[:seq_len]
    out = np.full(seq_len, PAD, np.int32)
    out[: len(seq)] = seq
    mask = np.zeros(seq_len, np.int32)
    mask[len(prompt): len(seq)] = 1      # train on continuation + EOS only
    return out, mask, L


def make_corpus(rng: np.random.Generator, n: int, seq_len: int):
    """(tokens (n, seq_len), mask (n, seq_len), topics (n,), lengths (n,))."""
    toks = np.zeros((n, seq_len), np.int32)
    masks = np.zeros((n, seq_len), np.int32)
    topics = rng.integers(0, N_TOPICS, size=n)
    lens = np.zeros(n, np.int64)
    for i in range(n):
        toks[i], masks[i], lens[i] = make_sequence(rng, int(topics[i]), seq_len)
    return toks, masks, topics, lens
