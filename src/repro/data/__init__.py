"""Data substrate: heavy-tailed prompt-conditioned length laws, calibrated
scenario generators (Track A), the theory-surrogate generator, a toy
tokenizer/corpus, and the sharded LM training pipeline."""

from repro.data.synthetic import ScenarioData, make_scenario  # noqa: F401
