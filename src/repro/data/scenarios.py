"""Scenario calibration — the eight (served model × scenario) settings.

Targets taken from the paper (A.4 + Table 1 + the S³ bin_max grids of A.2):

* median prompt-level noise radius (tokens):
  Qwen:  Math 27.8, Coding 21.7, LongSeq 42.9, Chat 35.3
  Llama: Math 16.1, Coding 23.0, LongSeq 38.0, Chat 33.4
* noise ratio (Median-MAE / prompt median): 11.5% (Qwen/Math) … 18.2% (Llama/LongSeq)
* representative max/median heavy-tail ratios 2–4×
* scenario length scales implied by the A.2 bin_max grids
  (Qwen: Math ≈ 1243-max grid, Coding ≈ 799, LongSeq ≈ 3262, Chat ≈ 6593)
* Chat is the hardest regime: its prompt medians are extremely dispersed and
  its features least informative (paper: ProD-D MAE ≈ 2× noise radius).

``feature_noise`` per view encodes each probe's information content —
last-token hidden state (best) > mean-pooled > auxiliary proxy (S³) >
entropy-pooled (EGTP, which the paper observes collapses onto early tokens).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.data.lengths import LengthLaw

MODELS = ("qwen", "llama")
SCENARIOS = ("math", "coding", "longseq", "chat")

# per-view latent-observation noise (σ in units of the latent scale) and
# pooled-view attenuation; chat multiplies feature noise further.
VIEW_NOISE = {"last": 0.12, "mean": 0.30, "proxy": 0.55, "entropy": 0.95}


@dataclass(frozen=True)
class ScenarioSpec:
    law: LengthLaw
    feature_hardness: float      # scales VIEW_NOISE (chat ≫ math)
    d_feature: int = 64
    paper_noise_radius: float = 0.0   # reference values for validation
    paper_bin_max: float = 0.0


_CAL: Dict[Tuple[str, str], ScenarioSpec] = {
    # (model, scenario): length law + feature hardness
    ("qwen", "math"): ScenarioSpec(
        LengthLaw(median_scale=240, median_spread=0.45, sigma_body=0.142,
                  tail_weight=0.028, tail_alpha=2.8),
        feature_hardness=1.0, paper_noise_radius=27.8, paper_bin_max=1243),
    ("qwen", "coding"): ScenarioSpec(
        LengthLaw(median_scale=165, median_spread=0.52, sigma_body=0.16,
                  tail_weight=0.028, tail_alpha=2.6),
        feature_hardness=1.1, paper_noise_radius=21.7, paper_bin_max=799),
    ("qwen", "longseq"): ScenarioSpec(
        LengthLaw(median_scale=330, median_spread=0.75, sigma_body=0.145,
                  tail_weight=0.035, tail_alpha=2.2),
        feature_hardness=1.35, paper_noise_radius=42.9, paper_bin_max=3262),
    ("qwen", "chat"): ScenarioSpec(
        LengthLaw(median_scale=260, median_spread=1.05, sigma_body=0.16,
                  tail_weight=0.018, tail_alpha=2.0),
        feature_hardness=2.6, paper_noise_radius=35.3, paper_bin_max=6593),
    ("llama", "math"): ScenarioSpec(
        LengthLaw(median_scale=130, median_spread=0.42, sigma_body=0.152,
                  tail_weight=0.028, tail_alpha=2.8),
        feature_hardness=1.0, paper_noise_radius=16.1, paper_bin_max=938),
    ("llama", "coding"): ScenarioSpec(
        LengthLaw(median_scale=150, median_spread=0.55, sigma_body=0.183,
                  tail_weight=0.032, tail_alpha=2.4),
        feature_hardness=1.15, paper_noise_radius=23.0, paper_bin_max=866),
    ("llama", "longseq"): ScenarioSpec(
        LengthLaw(median_scale=250, median_spread=0.72, sigma_body=0.162,
                  tail_weight=0.042, tail_alpha=2.0),
        feature_hardness=1.3, paper_noise_radius=38.0, paper_bin_max=2689),
    ("llama", "chat"): ScenarioSpec(
        LengthLaw(median_scale=215, median_spread=1.0, sigma_body=0.185,
                  tail_weight=0.012, tail_alpha=2.0),
        feature_hardness=2.5, paper_noise_radius=33.4, paper_bin_max=4422),
}

# paper's official split sizes (3.1); benchmarks default to reduced sizes on CPU
PAPER_SPLITS = {
    "math": (7473, 1319), "coding": (374, 500),
    "longseq": (3789, 961), "chat": (4070, 930),
}


ALL_SETTINGS: Tuple[Tuple[str, str], ...] = tuple(
    (m, s) for m in MODELS for s in SCENARIOS
)


def get_spec(model: str, scenario: str) -> ScenarioSpec:
    return _CAL[(model, scenario)]


def feature_sigma(spec: ScenarioSpec, view: str = "last") -> float:
    """Effective log-median observation noise of a probe view for a scenario:
    the per-view latent noise scaled by scenario feature hardness. This is the
    σ a trace-level predictor proxy corrupts log m with, so that prediction
    error tracks the paper's view-informativeness ordering (last > mean >
    proxy > entropy) and scenario difficulty (chat ≫ math)."""
    return spec.feature_hardness * VIEW_NOISE[view]
