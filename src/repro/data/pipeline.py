"""Sharded LM-training data pipeline.

Host-side numpy batching with deterministic shuffling, global-batch assembly,
and device placement via the mesh's batch sharding. On a real multi-pod
deployment each process feeds its addressable shard (``jax.process_index``
slicing is built in); on CPU everything degenerates to a local iterator.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.data.tokenizer import make_corpus


@dataclass
class LMDataset:
    tokens: np.ndarray       # (n, seq)
    loss_mask: np.ndarray    # (n, seq)

    def __len__(self):
        return self.tokens.shape[0]


def make_lm_dataset(n: int, seq_len: int, seed: int = 0) -> LMDataset:
    rng = np.random.default_rng(seed)
    toks, masks, _, _ = make_corpus(rng, n, seq_len)
    return LMDataset(tokens=toks, loss_mask=masks)


def batch_iterator(
    ds: LMDataset,
    global_batch: int,
    seed: int = 0,
    sharding: Optional[jax.sharding.Sharding] = None,
    drop_last: bool = True,
) -> Iterator[Dict[str, jax.Array]]:
    """Infinite epoch-shuffled iterator yielding device-placed batches."""
    n = len(ds)
    n_proc = jax.process_count()
    pidx = jax.process_index()
    per_proc = global_batch // n_proc
    epoch = 0
    while True:
        rng = np.random.default_rng(seed + epoch)
        perm = rng.permutation(n)
        for s in range(0, n - global_batch + 1 if drop_last else n, global_batch):
            idx = perm[s : s + global_batch]
            local = idx[pidx * per_proc : (pidx + 1) * per_proc]
            batch = {
                "tokens": ds.tokens[local],
                "loss_mask": ds.loss_mask[local],
            }
            if sharding is not None:
                batch = {
                    k: jax.make_array_from_process_local_data(sharding, v)
                    for k, v in batch.items()
                }
            yield batch
        epoch += 1
