"""Track-A scenario datasets: repeated-sampling lengths + feature views.

The latent (log m, σ, w, α) of each prompt drives its length distribution;
feature views are noisy nonlinear embeddings of those latents, with per-view
noise encoding each probe's information content (see ``scenarios.VIEW_NOISE``).
The head must learn view → conditional-median through the same nonlinearity
for every method — only the supervision target differs, as in the paper.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.data import scenarios as sc
from repro.data.lengths import (
    LengthLaw,
    sample_lengths,
    sample_prompt_latents,
    true_conditional_median,
)


@dataclass
class ScenarioData:
    model: str
    scenario: str
    r: int
    len_train: np.ndarray               # (N, r) int
    len_test: np.ndarray                # (Nt, r) int
    phi_train: Dict[str, np.ndarray]    # view -> (N, d)
    phi_test: Dict[str, np.ndarray]
    latents_train: np.ndarray           # (N, 4)
    latents_test: np.ndarray
    spec: sc.ScenarioSpec

    @property
    def true_median_train(self) -> np.ndarray:
        return true_conditional_median(self.latents_train)

    @property
    def true_median_test(self) -> np.ndarray:
        return true_conditional_median(self.latents_test)


def _feature_views(
    rng: np.random.Generator,
    latents: np.ndarray,
    spec: sc.ScenarioSpec,
    mixers: Dict[str, np.ndarray],
) -> Dict[str, np.ndarray]:
    """Noisy nonlinear embeddings of the prompt latents, one per probe view."""
    n = latents.shape[0]
    d = spec.d_feature
    z = latents.copy()
    z[:, 0] = (z[:, 0] - 5.0)            # center log-median roughly
    views = {}
    for view, base_noise in sc.VIEW_NOISE.items():
        noise = base_noise * spec.feature_hardness
        z_obs = z + noise * rng.standard_normal(z.shape) * np.array([1.0, 0.5, 0.25, 0.25])
        nuisance = rng.standard_normal((n, 4))
        inp = np.concatenate([z_obs, nuisance], axis=1)     # (n, 8)
        a, b = mixers[view]
        phi = np.tanh(inp @ a + b)                          # (n, d)
        views[view] = (phi / np.sqrt(d)).astype(np.float32)  # ‖φ‖₂ ≈ O(1)
    return views


def _make_mixers(rng: np.random.Generator, d: int) -> Dict[str, np.ndarray]:
    mixers = {}
    for view in sc.VIEW_NOISE:
        a = rng.standard_normal((8, d)) * 0.9
        b = 0.3 * rng.standard_normal(d)
        mixers[view] = (a, b)
    return mixers


def make_scenario(
    model: str,
    scenario: str,
    n_train: Optional[int] = None,
    n_test: Optional[int] = None,
    r: int = 16,
    seed: int = 0,
    full_paper_splits: bool = False,
) -> ScenarioData:
    spec = sc.get_spec(model, scenario)
    if full_paper_splits:
        n_train, n_test = sc.PAPER_SPLITS[scenario]
    n_train = n_train or 1500
    n_test = n_test or 400
    import zlib
    rng = np.random.default_rng(
        seed * 7919 + zlib.crc32(f"{model}/{scenario}".encode()) % 100003
    )
    mixers = _make_mixers(rng, spec.d_feature)  # frozen "model" per setting
    lat_tr = sample_prompt_latents(rng, spec.law, n_train)
    lat_te = sample_prompt_latents(rng, spec.law, n_test)
    len_tr = sample_lengths(rng, lat_tr, r, spec.law)
    len_te = sample_lengths(rng, lat_te, r, spec.law)
    return ScenarioData(
        model=model, scenario=scenario, r=r,
        len_train=len_tr, len_test=len_te,
        phi_train=_feature_views(rng, lat_tr, spec, mixers),
        phi_test=_feature_views(rng, lat_te, spec, mixers),
        latents_train=lat_tr, latents_test=lat_te, spec=spec,
    )


def surrogate_linear_data(
    n: int, d: int, eps: float = 0.5, v: float = 1.0, r: int = 16,
    S: float = 1.0, seed: int = 0,
):
    """Theorem-1 surrogate: L_i = φ(x_i)ᵀθ* + η_i with symmetric heavy-tailed η
    (student-t with df = 1 + 2ε ⇒ E|η|^{1+ε} finite), ‖φ‖₂ ≤ 1, ‖θ*‖₂ ≤ S.

    Returns (phi (n,d), eta (n,r), theta_star (d,)).
    """
    rng = np.random.default_rng(seed)
    theta = rng.standard_normal(d)
    theta = S * theta / np.linalg.norm(theta)
    phi = rng.standard_normal((n, d))
    phi = phi / np.maximum(np.linalg.norm(phi, axis=1, keepdims=True), 1.0)
    df = 1.0 + 2.0 * eps
    eta = rng.standard_t(df, size=(n, r))
    # scale to make E|η|^{1+ε} ≈ v (monte-carlo normalization)
    probe = rng.standard_t(df, size=200_000)
    scale = (v / np.mean(np.abs(probe) ** (1 + eps))) ** (1.0 / (1 + eps))
    return phi.astype(np.float64), (eta * scale), theta
