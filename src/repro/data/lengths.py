"""Heavy-tailed prompt-conditioned output-length laws.

Each prompt i carries latent (m_i, σ_i, w_i, α_i): conditional on the prompt,

    L  ~  m_i · LogNormal(0, σ_i)                 w.p. 1 − w_i   (body)
    L  ~  m_i · (1 + Pareto(α_i))                 w.p. w_i       (tail)

The lognormal body has median m_i (so the prompt median is stable), while the
Pareto tail produces the occasional multi-× generations the paper documents
(max/median 2–4× over 100 repeats). This is the data-generating family the
paper's Observations 1–2 are consistent with; Assumption 1's (1+ε)-moment
bound holds for α > 1 + ε.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class LengthLaw:
    """Scenario-level hyper-parameters for the per-prompt latents."""

    median_scale: float        # cross-prompt median of m_i
    median_spread: float       # lognormal σ of m_i across prompts
    sigma_body: float          # within-prompt lognormal σ (noise radius driver)
    tail_weight: float         # P(tail draw)
    tail_alpha: float          # Pareto index (smaller = heavier)
    min_len: int = 4
    max_len: int = 1 << 17


def sample_prompt_latents(
    rng: np.random.Generator, law: LengthLaw, n: int
) -> np.ndarray:
    """Per-prompt latent matrix z (n, 4): [log m, σ, w, α]."""
    log_m = np.log(law.median_scale) + law.median_spread * rng.standard_normal(n)
    sigma = law.sigma_body * np.exp(0.25 * rng.standard_normal(n))
    w = np.clip(law.tail_weight * np.exp(0.5 * rng.standard_normal(n)), 0.0, 0.4)
    alpha = np.clip(law.tail_alpha * np.exp(0.15 * rng.standard_normal(n)), 1.1, 8.0)
    return np.stack([log_m, sigma, w, alpha], axis=1)


def sample_lengths(
    rng: np.random.Generator, latents: np.ndarray, r: int, law: LengthLaw
) -> np.ndarray:
    """r independent generations per prompt. latents (n,4) -> lengths (n, r)."""
    n = latents.shape[0]
    m = np.exp(latents[:, 0])[:, None]
    sigma = latents[:, 1][:, None]
    w = latents[:, 2][:, None]
    alpha = latents[:, 3][:, None]
    body = m * np.exp(sigma * rng.standard_normal((n, r)))
    # Pareto tail via inverse CDF: L = m · u^{-1/α} ≥ m
    u = rng.random((n, r))
    tail = m * (u ** (-1.0 / alpha))
    pick_tail = rng.random((n, r)) < w
    L = np.where(pick_tail, tail, body)
    return np.clip(np.rint(L), law.min_len, law.max_len).astype(np.int64)


def true_conditional_median(latents: np.ndarray) -> np.ndarray:
    """Population median of the mixture ≈ body median m (tail weight ≤ 0.4
    keeps the mixture median inside the body; exact for w < 0.5 up to the
    body/tail overlap, adequate as the θ*-target for the theory checks)."""
    return np.exp(latents[:, 0])


def _ndtr(z: np.ndarray) -> np.ndarray:
    """Standard-normal CDF, vectorized. scipy when present, math.erf else."""
    try:
        from scipy.special import ndtr
        return ndtr(z)
    except ImportError:  # pragma: no cover - scipy ships in the image
        import math
        return 0.5 * (1.0 + np.vectorize(math.erf)(z / np.sqrt(2.0)))


def law_quantile(latents: np.ndarray, q: float) -> np.ndarray:
    """Per-prompt q-quantile of the body+tail length mixture, vectorized.

    CDF(x) = (1−w)·Φ((ln x − ln m)/σ) + w·[1 − (x/m)^{−α}]₊ has no closed
    inverse, so invert by geometric bisection in x. This is the exact
    distributional object a ProD-D head estimates — the serving layer uses it
    for quantile KV reservation at trace scale, where training a head per
    50k-request trace would dominate the benchmark."""
    latents = np.asarray(latents, np.float64)
    m = np.exp(latents[:, 0])
    sigma = np.maximum(latents[:, 1], 1e-6)
    w = np.clip(latents[:, 2], 0.0, 0.999)
    alpha = np.maximum(latents[:, 3], 1.01)

    def cdf(x):
        body = _ndtr((np.log(np.maximum(x, 1e-12)) - np.log(m)) / sigma)
        tail = np.where(x >= m, 1.0 - (np.maximum(x, 1e-12) / m) ** (-alpha),
                        0.0)
        return (1.0 - w) * body + w * tail

    lo = m * np.exp(-8.0 * sigma)
    # upper bracket: body saturates by e^{8σ}; the tail reaches q at
    # m·((1−q)/w)^{−1/α} once the body has saturated — take the max, doubled
    tail_hi = np.where(
        w > 1e-12,
        (np.maximum(1.0 - q, 1e-12) / np.maximum(w, 1e-12)) ** (-1.0 / alpha),
        1.0,
    )
    hi = 2.0 * m * np.maximum(np.exp(8.0 * sigma), np.maximum(tail_hi, 1.0))
    for _ in range(60):
        mid = np.sqrt(lo * hi)
        below = cdf(mid) < q
        lo = np.where(below, mid, lo)
        hi = np.where(below, hi, mid)
    return hi
