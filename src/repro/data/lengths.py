"""Heavy-tailed prompt-conditioned output-length laws.

Each prompt i carries latent (m_i, σ_i, w_i, α_i): conditional on the prompt,

    L  ~  m_i · LogNormal(0, σ_i)                 w.p. 1 − w_i   (body)
    L  ~  m_i · (1 + Pareto(α_i))                 w.p. w_i       (tail)

The lognormal body has median m_i (so the prompt median is stable), while the
Pareto tail produces the occasional multi-× generations the paper documents
(max/median 2–4× over 100 repeats). This is the data-generating family the
paper's Observations 1–2 are consistent with; Assumption 1's (1+ε)-moment
bound holds for α > 1 + ε.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class LengthLaw:
    """Scenario-level hyper-parameters for the per-prompt latents."""

    median_scale: float        # cross-prompt median of m_i
    median_spread: float       # lognormal σ of m_i across prompts
    sigma_body: float          # within-prompt lognormal σ (noise radius driver)
    tail_weight: float         # P(tail draw)
    tail_alpha: float          # Pareto index (smaller = heavier)
    min_len: int = 4
    max_len: int = 1 << 17


def sample_prompt_latents(
    rng: np.random.Generator, law: LengthLaw, n: int
) -> np.ndarray:
    """Per-prompt latent matrix z (n, 4): [log m, σ, w, α]."""
    log_m = np.log(law.median_scale) + law.median_spread * rng.standard_normal(n)
    sigma = law.sigma_body * np.exp(0.25 * rng.standard_normal(n))
    w = np.clip(law.tail_weight * np.exp(0.5 * rng.standard_normal(n)), 0.0, 0.4)
    alpha = np.clip(law.tail_alpha * np.exp(0.15 * rng.standard_normal(n)), 1.1, 8.0)
    return np.stack([log_m, sigma, w, alpha], axis=1)


def sample_lengths(
    rng: np.random.Generator, latents: np.ndarray, r: int, law: LengthLaw
) -> np.ndarray:
    """r independent generations per prompt. latents (n,4) -> lengths (n, r)."""
    n = latents.shape[0]
    m = np.exp(latents[:, 0])[:, None]
    sigma = latents[:, 1][:, None]
    w = latents[:, 2][:, None]
    alpha = latents[:, 3][:, None]
    body = m * np.exp(sigma * rng.standard_normal((n, r)))
    # Pareto tail via inverse CDF: L = m · u^{-1/α} ≥ m
    u = rng.random((n, r))
    tail = m * (u ** (-1.0 / alpha))
    pick_tail = rng.random((n, r)) < w
    L = np.where(pick_tail, tail, body)
    return np.clip(np.rint(L), law.min_len, law.max_len).astype(np.int64)


def true_conditional_median(latents: np.ndarray) -> np.ndarray:
    """Population median of the mixture ≈ body median m (tail weight ≤ 0.4
    keeps the mixture median inside the body; exact for w < 0.5 up to the
    body/tail overlap, adequate as the θ*-target for the theory checks)."""
    return np.exp(latents[:, 0])
