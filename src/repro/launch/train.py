"""Training launcher.

CPU-scale real run (tiny/reduced configs) or production lowering (full
configs on a TPU mesh — on this container use dryrun.py for full configs).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke --steps 5
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_config, list_archs
from repro.data.pipeline import batch_iterator, make_lm_dataset
from repro.models.model_zoo import Runtime, build_model
from repro.training.trainer import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-data", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke or args.arch != "tiny-lm":
        cfg = cfg.reduced()
    cfg = cfg.with_overrides(dtype="float32")
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("use family-specific examples for vlm/encdec training")
    model = build_model(cfg)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       decay_steps=args.steps, seed=args.seed, remat="none")
    ds = make_lm_dataset(args.n_data, args.seq, seed=args.seed)
    # clamp token ids into this model's vocab
    ds.tokens = np.minimum(ds.tokens, cfg.vocab_size - 1)
    it = batch_iterator(ds, args.batch, seed=args.seed)
    state = train_loop(model, tcfg, it, args.steps, rt=Runtime.local(),
                       ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 2, 1))
    print(f"finished at step {int(state.step)}")


if __name__ == "__main__":
    main()
