import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture × input shape × mesh) combination this lowers and
compiles the real step function — train_step for train shapes, prefill/serve
steps (with the fused ProD length-prediction head) for inference shapes —
against ShapeDtypeStruct stand-ins (no allocation), then records:

* ``memory_analysis()``  — proves the per-chip working set,
* ``cost_analysis()``    — per-device HLO FLOPs / bytes,
* a collective-traffic estimate parsed from the partitioned HLO text
  (all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),

and derives the three v5e roofline terms (EXPERIMENTS.md §Roofline).

NOTE: the XLA_FLAGS line above must execute before ANY other jax import —
keep it the first statement of this file. Do not set that env var globally.
"""

import argparse
import gc
import json
import re
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.common.config import INPUT_SHAPES, get_input_shape
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.mesh import (
    HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, n_chips,
)
from repro.launch import hlo_analysis
from repro.launch.workload import build_steps, cfg_for_shape

_DT_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(dt: str, dims: str) -> int:
    b = _DT_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


_OPCODE_RE = re.compile(r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)\s+([\w\-]+)\(")
_WHILE_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_DEF_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")


def _instruction_traffic(rhs: str, opcode: str) -> float:
    head = rhs.split(opcode, 1)[0]
    result_bytes = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(head))
    operands = rhs.split("(", 1)[1] if "(" in rhs else ""
    operand_bytes = [_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(operands)]
    if opcode == "all-reduce":
        return 2.0 * result_bytes
    if opcode == "reduce-scatter":
        return float(max(operand_bytes or [result_bytes]))
    return float(result_bytes)


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic from the partitioned HLO, with while-loop
    bodies weighted by their ``known_trip_count`` (a layer scan executes its
    collectives L times but they appear once in the text).

    Traffic model (ring algorithms, per-chip): all-reduce ≈ 2x result bytes;
    all-gather ≈ result bytes; reduce-scatter ≈ max operand bytes;
    all-to-all / collective-permute ≈ result bytes.
    """
    # ---- split into computations ------------------------------------------
    comps: Dict[str, list] = {}
    current = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_DEF_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                current = m.group(2).lstrip("%")
                comps[current] = []
                if m.group(1):
                    entry = current
                continue
        if line.strip() == "}":
            continue
        if current is not None:
            comps[current].append(line.strip())

    # ---- per-computation own traffic + callee edges ------------------------
    own = {c: {op: 0.0 for op in _COLL_OPS} for c in comps}
    counts = {c: {op: 0 for op in _COLL_OPS} for c in comps}
    calls: Dict[str, list] = {c: [] for c in comps}  # (callee, multiplier)
    for cname, lines in comps.items():
        for ls in lines:
            if "=" not in ls:
                continue
            mo = _OPCODE_RE.search(ls)
            if mo is None:
                continue
            opcode = mo.group(1)
            base = opcode.replace("-start", "").replace("-done", "")
            if base in _COLL_OPS and not opcode.endswith("-done"):
                rhs = ls.split("=", 1)[1]
                own[cname][base] += _instruction_traffic(rhs, base)
                counts[cname][base] += 1
            elif opcode == "while":
                bm = _WHILE_BODY_RE.search(ls)
                tm = _TRIP_RE.search(ls)
                trips = int(tm.group(1)) if tm else 1
                if bm:
                    calls[cname].append((bm.group(1).lstrip("%"), trips))
            else:
                for callee in re.findall(
                        r"(?:calls|to_apply|body|branch_computations)=\{?(%[\w.\-]+)", ls):
                    calls[cname].append((callee.lstrip("%"), 1))

    # ---- effective traffic from ENTRY (memoized DAG walk) ------------------
    memo: Dict[str, Dict[str, float]] = {}

    def eff(c: str, depth=0) -> Dict[str, float]:
        if c in memo:
            return memo[c]
        if depth > 16 or c not in comps:
            return {op: 0.0 for op in _COLL_OPS}
        tot = dict(own[c])
        for callee, mult in calls[c]:
            sub = eff(callee, depth + 1)
            for op in _COLL_OPS:
                tot[op] += mult * sub[op]
        memo[c] = tot
        return tot

    root = entry or (max(comps, key=lambda c: len(comps[c])) if comps else None)
    totals = eff(root) if root else {op: 0.0 for op in _COLL_OPS}
    static_counts = {op: sum(counts[c][op] for c in comps) for op in _COLL_OPS}
    return {**{f"{k}_bytes": v for k, v in totals.items()},
            **{f"{k}_count": static_counts[k] for k in static_counts},
            "total_bytes": sum(totals.values())}


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D (train) or 2·N_active·D (inference);
    D = global tokens processed by the step."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/request


def run_one(arch: str, shape_name: str, multi_pod: bool,
            variant: str = "baseline") -> dict:
    shape = get_input_shape(shape_name)
    base_cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(mesh)
    t0 = time.time()
    result = dict(arch=arch, shape=shape_name, mesh="multipod" if multi_pod
                  else "pod", chips=chips, variant=variant, ok=False)
    try:
        built = build_steps(base_cfg, shape, mesh=mesh, variant=variant)
        cfg = built["cfg"]
        with mesh:
            jitted = jax.jit(
                built["step"],
                in_shardings=built["arg_shardings"],
                out_shardings=built["out_shardings"],
            )
            lowered = jitted.lower(*built["arg_specs"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            ma = compiled.memory_analysis()
            mem = {
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
            }
        except Exception as e:  # CPU backend may not implement it
            mem = {"error": str(e)}
        hlo = compiled.as_text()
        stats = hlo_analysis.analyze(hlo)
        coll = stats.as_dict()
        flops_dev = stats.flops               # trip-count-weighted, per device
        bytes_dev = stats.hbm_bytes

        mf = model_flops(cfg, shape)
        flops_global = flops_dev * chips
        compute_term = flops_dev / PEAK_FLOPS_BF16
        memory_term = bytes_dev / HBM_BW
        collective_term = stats.total_coll_bytes / ICI_BW
        terms = {"compute_s": compute_term, "memory_s": memory_term,
                 "collective_s": collective_term}
        dominant = max(terms, key=terms.get)

        arg_bytes = mem.get("argument_bytes", 0)
        result.update(
            ok=True,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops_per_device=flops_dev, bytes_per_device=bytes_dev,
            xla_cost_flops=float(cost.get("flops", 0.0)),
            xla_cost_bytes=float(cost.get("bytes accessed", 0.0)),
            collectives=coll, memory=mem,
            hbm_ok=(bool(mem.get("peak_bytes", 0) <= HBM_BYTES)
                    if mem.get("peak_bytes") else
                    (bool(arg_bytes + mem.get("temp_bytes", 0) <= HBM_BYTES)
                     if "error" not in mem else None)),
            roofline={**terms, "dominant": dominant,
                      "model_flops": mf,
                      "useful_flops_ratio": mf / max(flops_global, 1.0)},
            attn_variant=("window" if cfg.attn_window and not base_cfg.attn_window
                          else "native"),
            head_padding={
                "n_heads": [base_cfg.n_heads, cfg.n_heads],
                "n_kv_heads": [base_cfg.n_kv_heads, cfg.n_kv_heads],
                "ssm_heads": [base_cfg.ssm_n_heads, cfg.ssm_n_heads]
                if cfg.family in ("ssm", "hybrid") else None,
            },
        )
    except Exception as e:
        import traceback
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    result["total_s"] = round(time.time() - t0, 1)
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in INPUT_SHAPES] + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else [s.name for s in INPUT_SHAPES]
    meshes = [args.mesh] if args.mesh != "both" else ["pod", "multipod"]
    os.makedirs(args.out_dir, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}_{shape}_{mesh_kind}".replace("/", "-")
                if args.variant != "baseline":
                    tag += f"_{args.variant}" 
                path = os.path.join(args.out_dir, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"skip (exists): {tag}")
                    continue
                print(f"=== {tag} ===", flush=True)
                res = run_one(arch, shape, multi_pod=(mesh_kind == "multipod"),
                              variant=args.variant)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1, default=float)
                status = "OK" if res["ok"] else f"FAIL: {res.get('error')}"
                rt = res.get("roofline", {})
                print(f"  -> {status}  compile={res.get('compile_s')}s "
                      f"dominant={rt.get('dominant')} "
                      f"terms={ {k: f'{v:.2e}' for k, v in rt.items() if k.endswith('_s')} }",
                      flush=True)
                gc.collect()


if __name__ == "__main__":
    main()
