"""Workload construction for the multi-pod dry-run: per-(arch × shape) config
overrides, ShapeDtypeStruct input specs, sharding assignment, and the three
step functions (train / prefill+predict / decode+predict).

The ProD head is a first-class part of the serving steps: prefill returns
(last-token logits, cache, length distribution, median prediction) — the
paper's "reuse the served LLM's hidden states, single-shot, no auxiliary
model" integration. Decode optionally re-predicts remaining length online
(the paper's §5 future-work hook, TRAIL-style).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import InputShape, ModelConfig, TrainConfig
from repro.common.sharding import default_rules, tree_shardings
from repro.kernels import ops as kops
from repro.models.layers import unembed
from repro.models.model_zoo import Model, Runtime, build_model, last_token_hidden
from repro.training.trainer import make_train_step
from repro.training.optim import make_optimizer


# ---------------------------------------------------------------------------
# per-shape config adaptation
# ---------------------------------------------------------------------------


def cfg_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k requires sub-quadratic attention / sub-linear KV memory.

    * ssm / hybrid / gemma3 run their native mechanism (gemma3's 10 global
      layers keep the full 500k cache, context-parallel over the data axis);
    * all other attention archs run the documented sliding-window decode
      variant (8192-token KV ring) — full-attention 500k is NOT claimed.
    """
    if shape.name == "long_500k":
        if cfg.family == "ssm" or cfg.attn_window or cfg.local_global_ratio:
            return cfg
        return cfg.with_overrides(attn_window=8192)
    return cfg


def tpu_shardable_cfg(cfg: ModelConfig, model_axis: int) -> ModelConfig:
    """Pad head counts to make attention/SSD shardable over the model axis.

    With a fixed 16-way tensor axis, head counts not divisible by 16 leave the
    whole attention (or SSD) computation REPLICATED across the axis — a 16×
    compute/bytes overhead the dry-run exposed on yi-34b (56 heads). The
    TPU-native fix (MaxText-style) is to pad:

    * GQA: pad q-heads-per-group so kv_heads × G' is divisible (yi: G 7→8);
    * MHA: pad whole (q,k,v) head triplets (whisper 20→32, minicpm 36→48);
    * SSD: pad state heads (mamba2 24→32).

    head_dim is preserved; this is a documented architectural adaptation (the
    `nopad` dry-run variant measures the cost of not doing it).
    """
    kw = {}
    if cfg.family != "ssm" and cfg.n_heads % model_axis:
        KV, G = cfg.n_kv_heads, cfg.q_per_kv
        if KV % model_axis == 0 or (G > 1 and KV < model_axis):
            # pad G until KV*G divisible by axis (keeps kv cache size)
            Gp = G
            while (KV * Gp) % model_axis:
                Gp += 1
            kw.update(n_heads=KV * Gp)
        else:
            # MHA-style: pad whole head triplets
            Hp = cfg.n_heads
            while Hp % model_axis:
                Hp += 1
            kw.update(n_heads=Hp, n_kv_heads=Hp if KV == cfg.n_heads else KV)
    if cfg.family in ("ssm", "hybrid") and cfg.ssm_n_heads % model_axis:
        Hs = cfg.ssm_n_heads
        while Hs % model_axis:
            Hs += 1
        kw.update(ssm_heads=Hs)
    return cfg.with_overrides(**kw) if kw else cfg


def train_cfg_for(cfg: ModelConfig) -> TrainConfig:
    """Arch-appropriate training setup for the dry-run train_step."""
    opt = "adafactor" if cfg.param_count() > 1e11 else "adamw"
    sched = "wsd" if cfg.name.startswith("minicpm") else "cosine"
    return TrainConfig(optimizer=opt, schedule=sched, stable_steps=1000,
                       remat="full")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; zero allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(
    cfg: ModelConfig, shape: InputShape
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (specs, logical_axes) for the model-input batch."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    def add(name, shp, dt, ax):
        specs[name] = _sds(shp, dt)
        axes[name] = ax

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            add("embeds", (B, S, cfg.d_model), cfg.dtype,
                ("batch", "seq", "act_embed"))
            add("positions", (3, B, S), "int32", (None, "batch", "seq"))
            if shape.kind == "train":
                # next-token targets (text stream) alongside the embeddings
                add("tokens", (B, S), "int32", ("batch", "seq"))
        else:
            add("tokens", (B, S), "int32", ("batch", "seq"))
        if cfg.family == "encdec":
            if "tokens" not in specs:
                add("tokens", (B, S), "int32", ("batch", "seq"))
            add("enc_embeds", (B, cfg.encoder_seq, cfg.d_model), cfg.dtype,
                ("batch", "seq", "act_embed"))
        if shape.kind == "train":
            add("loss_mask", (B, S), "int32", ("batch", "seq"))
        else:
            add("lengths", (B,), "int32", ("batch",))
    else:  # decode
        add("tokens", (B,), "int32", ("batch",))
        add("pos", (B,), "int32", ("batch",))
        add("lengths", (B,), "int32", ("batch",))
    return specs, axes


def head_specs(cfg: ModelConfig) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    d, h, K = cfg.d_model, cfg.predictor_hidden, cfg.predictor_bins
    specs = {
        "w1": _sds((d, h), "float32"), "b1": _sds((h,), "float32"),
        "w2": _sds((h, K), "float32"), "b2": _sds((K,), "float32"),
        "edges": _sds((K + 1,), "float32"),
    }
    axes = {
        "w1": ("embed", "pred_hidden"), "b1": ("pred_hidden",),
        "w2": ("pred_hidden", "bins"), "b2": ("bins",), "edges": (None,),
    }
    return specs, axes


def opt_state_axes(params_axes: Any, optimizer: str) -> Any:
    """Optimizer-state logical axes: like the params but with the weight
    d_model dim remapped to ``opt_embed`` → ZeRO-sharded over the data axes
    (moments are only touched elementwise, so any sharding is legal)."""
    is_ax = lambda x: isinstance(x, tuple)
    zero = lambda ax: tuple("opt_embed" if a == "embed" else a for a in ax)
    if optimizer == "adamw":
        remapped = jax.tree_util.tree_map(zero, params_axes, is_leaf=is_ax)
        return {"m": remapped, "v": remapped}

    def st(ax):
        ax = zero(ax)
        if len(ax) >= 2:
            return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
        return {"v": ax}

    return jax.tree_util.tree_map(st, params_axes, is_leaf=is_ax)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    # §Perf iteration knobs (EXPERIMENTS.md):
    "causal_skip": {"causal_skip": True},
    "moe_tight": {"moe_cap_slack": 1.0},
    "moe_partial": {"moe_fsdp_mode": "partial"},
    "int8kv": {"kv_quant": True},
    "seqpar": {"seq_shard": True},
    "nopad": {"pad_heads": False},
    # composites used by the hillclimbs
    "train_opt": {"causal_skip": True, "moe_cap_slack": 1.0, "seq_shard": True},
    "train_tight": {"causal_skip": True, "moe_cap_slack": 1.0},
    "decode_opt": {"kv_quant": True, "moe_fsdp_mode": "partial"},
}


def build_steps(cfg: ModelConfig, shape: InputShape, mesh=None,
                pad_heads: bool = True, variant: str = "baseline") -> Dict[str, Any]:
    """Assemble everything the dry-run needs for one (arch, shape) pair:

    returns dict with: step (callable), arg_specs (tuple of pytrees of
    ShapeDtypeStruct), arg_shardings (matching pytrees of NamedSharding),
    out_shardings (prefix pytree or None), model, cfg.
    """
    knobs = dict(VARIANTS[variant])
    if not knobs.pop("pad_heads", True):
        pad_heads = False
    cfg = cfg_for_shape(cfg, shape)
    if mesh is not None and pad_heads and "model" in mesh.axis_names:
        cfg = tpu_shardable_cfg(cfg, int(mesh.shape["model"]))
    model = build_model(cfg)
    rt = Runtime(mesh=mesh, remat="full" if shape.kind == "train" else "none",
                 **knobs)
    rules = None
    if mesh is not None:
        rules = default_rules(mesh)
        if shape.kind == "decode":
            # KV-cache layout: shard kv-heads over `model` when divisible;
            # otherwise context-parallel — shard the cache sequence dim over
            # `model` (flash-decode partial softmax + all-reduce). long_500k
            # (batch=1) additionally spreads the sequence over the free data
            # axes. Without this, a 32k×128-request GQA cache is 64 GB/chip.
            kv_ok = cfg.n_kv_heads % int(mesh.shape.get("model", 1)) == 0
            long_ctx = shape.name == "long_500k"
            if kv_ok:
                rules["cache_seq"] = ("data",) if long_ctx else None
            else:
                rules["cache_seq"] = ("data", "model") if long_ctx else ("model",)

    def shard(axes_tree, shape_tree):
        if mesh is None:
            return None
        return tree_shardings(axes_tree, shape_tree, mesh, rules)

    p_shapes = model.param_shapes()
    p_axes = model.param_axes()
    p_shard = shard(p_axes, p_shapes)
    b_specs, b_axes = input_specs(cfg, shape)
    b_shard = shard(b_axes, b_specs)

    if shape.kind == "train":
        tcfg = train_cfg_for(cfg)
        opt = make_optimizer(tcfg)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_axes = opt_state_axes(p_axes, tcfg.optimizer)
        o_shard = shard(o_axes, o_shapes)
        state_specs = {"params": p_shapes, "opt_state": o_shapes,
                       "step": _sds((), "float32")}
        state_shard = (
            {"params": p_shard, "opt_state": o_shard,
             "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
            if mesh is not None else None
        )
        step = make_train_step(model, tcfg, rt)
        return dict(
            step=step, arg_specs=(state_specs, b_specs),
            arg_shardings=(state_shard, b_shard) if mesh is not None else None,
            out_shardings=(state_shard, None) if mesh is not None else None,
            model=model, cfg=cfg, tcfg=tcfg,
        )

    h_specs, h_axes = head_specs(cfg)
    h_shard = shard(h_axes, h_specs)

    if shape.kind == "prefill":

        def prefill_step(params, head, batch):
            _, hidden, cache, _ = model.prefill(params, batch, rt,
                                                logits_mode="none")
            phi = last_token_hidden(hidden, batch["lengths"])
            last_logits = unembed(phi, params["embed"], params.get("head"))
            probs, pred = kops.prod_head(
                phi, head["w1"], head["b1"], head["w2"], head["b2"],
                head["edges"], impl="xla",
            )
            return last_logits, cache, probs, pred

        return dict(
            step=prefill_step, arg_specs=(p_shapes, h_specs, b_specs),
            arg_shardings=(p_shard, h_shard, b_shard) if mesh is not None else None,
            out_shardings=None, model=model, cfg=cfg,
        )

    # decode: one token vs. a cache of shape.seq_len
    c_shapes = model.cache_shapes(shape.global_batch, shape.seq_len,
                                  kv_quant=rt.kv_quant)
    c_axes = model.cache_axes(kv_quant=rt.kv_quant)
    c_shard = shard(c_axes, c_shapes)

    def decode_step(params, head, batch, cache):
        logits, hidden, new_cache = model.decode_step(params, batch, cache, rt)
        probs, pred = kops.prod_head(
            hidden, head["w1"], head["b1"], head["w2"], head["b2"],
            head["edges"], impl="xla",
        )
        return logits, new_cache, pred

    return dict(
        step=decode_step, arg_specs=(p_shapes, h_specs, b_specs, c_shapes),
        arg_shardings=(p_shard, h_shard, b_shard, c_shard)
        if mesh is not None else None,
        out_shardings=(None, c_shard, None) if mesh is not None else None,
        model=model, cfg=cfg,
    )
