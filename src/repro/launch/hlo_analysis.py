"""Static analyzer for partitioned HLO text → roofline terms.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, but a layer
scan executes it L times — so both FLOPs and bytes would be undercounted by
~L×. This walker parses the HLO module into computations, builds a per-
computation symbol table (every instruction line defines ``%name = shape op``),
and accumulates three trip-count-weighted quantities from the ENTRY:

* **flops**  — 2 · |result| · |contracted dims| for every ``dot`` (recursing
  into fusions/calls/while bodies; MXU work),
* **hbm bytes** — Σ (operand + result bytes) over *top-level* kernel
  instructions (fusions, dots, copies, slices, …) — fusion internals are
  VMEM-resident and excluded; while bodies are weighted by trip count,
* **collective bytes** — ring-model traffic per chip: all-reduce ≈ 2× result,
  all-gather ≈ result, reduce-scatter ≈ max operand, all-to-all /
  collective-permute ≈ result.

All quantities are per-device (the partitioned module has per-device shapes).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DT_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+([\w\-]+)\((.*)$"
)
_COMP_DEF_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=\{?(%[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")

# HBM-traffic model: the CPU backend fuses differently from TPU (and inserts
# loop-invariant copies / materialized converts TPU would never emit), so we
# count only genuine materialization points:
#   * matmuls: operands + result (weight streams, activation reads/writes)
#   * dynamic-slice / gather: the sliced bytes (scan-stacked weight streaming)
#   * dynamic-update-slice / scatter: 2 × update bytes (in-place RMW of the
#     slice — stacked grad buffers, KV-cache writes)
#   * collectives: operands + result
# Fusions dispatch on their root op; convert/copy/pad/elementwise(-rooted)
# fusions are assumed fused into consumers on TPU and contribute nothing.
_DOT_OPS = {"dot", "ragged-dot", "convolution"}
_SLICE_READ_OPS = {"dynamic-slice", "gather"}
_SLICE_WRITE_OPS = {"dynamic-update-slice", "scatter"}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) across all shapes found in ``text``."""
    elems, bts = 0, 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DT_BYTES[dt]
    return elems, bts


def _shape_dims(text: str) -> List[List[int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DT_BYTES:
            continue
        out.append([int(d) for d in dims.split(",") if d])
    return out


_META_RE = re.compile(r'op_name="([^"]*)"')

# instructions whose op_name contains this marker belong to a region that runs
# as a Pallas kernel on TPU: their intermediates are VMEM-resident, so they
# contribute FLOPs and collectives but no HBM traffic.
FUSED_KERNEL_MARKER = "fusedkernel_"


@dataclass
class Instr:
    name: str
    result: str          # result shape text (may be tuple)
    opcode: str
    rest: str            # everything after the opening paren
    in_kernel: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # %name -> shape text
    producer: Dict[str, "Instr"] = field(default_factory=dict)
    root: Optional["Instr"] = None


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: {o: 0.0 for o in COLL_OPS})
    coll_counts: Dict[str, int] = field(default_factory=lambda: {o: 0 for o in COLL_OPS})

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    def as_dict(self) -> dict:
        d = {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
             "total_bytes": self.total_coll_bytes}
        d.update({f"{k}_bytes": v for k, v in self.coll_bytes.items()})
        d.update({f"{k}_count": v for k, v in self.coll_counts.items()})
        return d


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line.strip():
            continue
        stripped = line.strip()
        if not line.startswith(" ") and stripped.endswith("{"):
            m = _COMP_DEF_RE.match(stripped)
            if m:
                current = Computation(name=m.group(2).lstrip("%"))
                comps[current.name] = current
                if m.group(1):
                    entry = current.name
                continue
        if stripped == "}":
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(stripped)
        if im:
            meta = _META_RE.search(stripped)
            ins = Instr(name=im.group(1), result=im.group(2),
                        opcode=im.group(3), rest=im.group(4),
                        in_kernel=bool(meta and FUSED_KERNEL_MARKER in meta.group(1)))
            current.instrs.append(ins)
            current.shapes[ins.name] = ins.result
            current.producer[ins.name] = ins
            if stripped.startswith("ROOT"):
                current.root = ins
        elif "parameter(" in stripped and "=" in stripped:
            # e.g. "%p = f32[..] parameter(0)" already matched; fallback no-op
            pass
    return comps, entry


def _operand_names(rest: str) -> List[str]:
    """Operand %names inside the call parens (stop at attribute list)."""
    depth = 1
    args = []
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = _OPERAND_RE.findall(rest[:i])
                break
    else:
        args = _OPERAND_RE.findall(rest)
    return args


def _instr_hbm_bytes(ins: Instr, comp: Computation,
                     comps: Dict[str, Computation]) -> float:
    """HBM traffic attributed to one top-level instruction (see model above)."""
    op_names = _operand_names(ins.rest)

    def operand_bytes(slice_caps: Optional[Dict[int, int]] = None) -> float:
        tot = 0.0
        for i_op, n in enumerate(op_names):
            full = _shape_elems_bytes(comp.shapes.get(n, ""))[1]
            if slice_caps and i_op in slice_caps:
                tot += min(full, slice_caps[i_op])
            else:
                tot += full
        return tot

    _, res_b = _shape_elems_bytes(ins.result)
    if ins.opcode in _DOT_OPS:
        return res_b + operand_bytes()
    if ins.opcode in _SLICE_READ_OPS:
        return float(res_b)
    if ins.opcode in _SLICE_WRITE_OPS:
        upd = (_shape_elems_bytes(comp.shapes.get(op_names[1], ""))[1]
               if len(op_names) > 1 else res_b)
        return 2.0 * upd
    if ins.opcode == "custom-call":
        return res_b + operand_bytes()
    if ins.opcode != "fusion":
        return 0.0
    # fusion: dispatch on the fused computation's root
    cm = _CALLS_RE.search(ins.rest)
    callee = comps.get(cm.group(1).lstrip("%")) if cm else None
    if callee is None or callee.root is None:
        return 0.0
    root = _effective_root(callee)
    if root.opcode in _DOT_OPS or root.opcode == "reduce":
        return res_b + operand_bytes(_fusion_param_bytes(callee))
    if root.opcode in _SLICE_READ_OPS:
        return float(_shape_elems_bytes(root.result)[1])
    if root.opcode in _SLICE_WRITE_OPS:
        r_ops = _operand_names(root.rest)
        upd = (_shape_elems_bytes(callee.shapes.get(r_ops[1], ""))[1]
               if len(r_ops) > 1 else 0)
        return 2.0 * upd
    # convert/copy/pad/elementwise-rooted fusions: fused into consumers on TPU
    return 0.0


def _effective_root(comp: Computation) -> Instr:
    """Unwrap layout-only root wrappers (bitcast/copy/convert/transpose/
    reshape) to the instruction that actually defines the fusion's kind —
    e.g. a ``bitcast(dynamic-update-slice(...))``-rooted fusion is a slice
    write, not an elementwise fusion."""
    root = comp.root
    seen = 0
    while root is not None and seen < 8 and root.opcode in (
            "bitcast", "copy", "convert", "transpose", "reshape"):
        ops = _operand_names(root.rest)
        nxt = comp.producer.get(ops[0]) if ops else None
        if nxt is None:
            break
        root = nxt
        seen += 1
    return root or comp.root


def _fusion_param_bytes(comp: Optional[Computation]) -> Dict[int, int]:
    """For a fused computation: parameter index -> effective read bytes when
    the parameter is consumed via dynamic-slice/gather (weight streaming out
    of a scan-stacked tensor reads one slice per trip, not the whole stack)."""
    if comp is None:
        return {}
    param_of = {}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                param_of[ins.name] = int(m.group(1))
    eff: Dict[int, int] = {}
    for ins in comp.instrs:
        if ins.opcode in ("dynamic-slice", "gather", "slice"):
            ops = _operand_names(ins.rest)
            if ops and ops[0] in param_of:
                _, b = _shape_elems_bytes(ins.result)
                idx = param_of[ops[0]]
                eff[idx] = eff.get(idx, 0) + b
    return eff


def analyze(text: str) -> HloStats:
    comps, entry = parse_module(text)
    memo: Dict[str, HloStats] = {}

    def comp_stats(cname: str, depth: int = 0) -> HloStats:
        if cname in memo:
            return memo[cname]
        st = HloStats()
        if depth > 20 or cname not in comps:
            return st
        comp = comps[cname]

        def add_child(callee: str, mult: float):
            sub = comp_stats(callee, depth + 1)
            st.flops += mult * sub.flops
            st.hbm_bytes += mult * sub.hbm_bytes
            for op in COLL_OPS:
                st.coll_bytes[op] += mult * sub.coll_bytes[op]

        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "").replace("-done", "")
            # ---- collectives ----
            if base in COLL_OPS:
                if ins.opcode.endswith("-done"):
                    continue
                _, res_b = _shape_elems_bytes(ins.result)
                op_names = _operand_names(ins.rest)
                op_b = [
                    _shape_elems_bytes(comp.shapes.get(n, ""))[1] for n in op_names
                ]
                if base == "all-reduce":
                    traffic = 2.0 * res_b
                elif base == "reduce-scatter":
                    traffic = float(max(op_b or [res_b]))
                else:
                    traffic = float(res_b)
                st.coll_bytes[base] += traffic
                st.coll_counts[base] += 1
                st.hbm_bytes += res_b + sum(op_b)
                continue
            # ---- control flow ----
            if ins.opcode == "while":
                tm = _TRIP_RE.search(ins.rest)
                bm = _WHILE_BODY_RE.search(ins.rest)
                trips = int(tm.group(1)) if tm else 1
                if bm:
                    add_child(bm.group(1).lstrip("%"), trips)
                continue
            if ins.opcode in ("call", "fusion", "conditional", "custom-call",
                              "async-start", "map", "reduce", "sort", "scatter",
                              "select-and-scatter", "reduce-window"):
                for cm in _CALLS_RE.finditer(ins.rest):
                    callee = cm.group(1).lstrip("%")
                    # fusion internals: FLOPs recurse, bytes do not (VMEM)
                    sub = comp_stats(callee, depth + 1)
                    st.flops += sub.flops
                    for op in COLL_OPS:
                        st.coll_bytes[op] += sub.coll_bytes[op]
            # ---- flops: dots ----
            if ins.opcode in ("dot", "ragged-dot"):
                res_dims = _shape_dims(ins.result)
                contract = _CONTRACT_RE.search(ins.rest)
                op_names = _operand_names(ins.rest)
                lhs_shape = _shape_dims(comp.shapes.get(op_names[0], "")) if op_names else []
                n_res = 1
                for d in (res_dims[0] if res_dims else []):
                    n_res *= d
                n_con = 1
                if contract and lhs_shape:
                    for idx in contract.group(1).split(","):
                        if idx:
                            n_con *= lhs_shape[0][int(idx)]
                st.flops += 2.0 * n_res * n_con
            elif ins.opcode == "convolution":
                # rough: 2 * |result| * (contracted window)  — unused by our models
                _, res_b = _shape_elems_bytes(ins.result)
                st.flops += 2.0 * res_b
            # ---- hbm bytes: materialization points only ----
            if ins.in_kernel:
                # Pallas-kernel (VMEM) region: intermediates are free, but
                # tensors crossing INTO the kernel (KV caches, q/k/v panels —
                # producers outside the scope) are genuine HBM reads.
                for n in _operand_names(ins.rest):
                    prod = comp.producer.get(n)
                    if prod is None or not prod.in_kernel:
                        st.hbm_bytes += _shape_elems_bytes(comp.shapes.get(n, ""))[1]
                continue
            st.hbm_bytes += _instr_hbm_bytes(ins, comp, comps)

        memo[cname] = st
        return st

    root = entry or (max(comps, key=lambda c: len(comps[c].instrs)) if comps else None)
    if root is None:
        return HloStats()
    res = comp_stats(root)
    # aggregate static counts over all computations for reporting
    total_counts = {op: 0 for op in COLL_OPS}
    for c in comps.values():
        for ins in c.instrs:
            base = ins.opcode.replace("-start", "").replace("-done", "")
            if base in COLL_OPS and not ins.opcode.endswith("-done"):
                total_counts[base] += 1
    res.coll_counts = total_counts
    return res
