"""Serving launcher: run the continuous-batching engine with ProD scheduling.

Two modes:
  --mode sim   discrete-event simulator over a calibrated scenario workload,
               comparing FCFS/max-reserve against ProD-driven SJF + quantile
               reservation (Track A).
  --mode real  actually decode the tiny LM with batched requests, train the
               ProD head from its own repeated generations, and report MAE
               (Track B).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PredictorConfig, ServeConfig
from repro.configs import get_config
from repro.core import bins as bins_mod
from repro.core import targets as targets_mod
from repro.core.metrics import mae, noise_radius
from repro.core.predictor import train_predictor
from repro.data import make_scenario
from repro.models.model_zoo import Runtime, build_model
from repro.serving.engine import RealEngine, SimEngine
from repro.serving.request import workload_from_scenario
from repro.serving.scheduler import Policy


def run_sim(args):
    data = make_scenario(args.model_tag, args.scenario,
                         n_train=args.n_train, n_test=max(args.n_requests, 200),
                         seed=args.seed)
    bin_max = float(np.quantile(data.len_train, 0.999) * 1.3)
    pcfg = PredictorConfig(n_bins=64, bin_max=bin_max, epochs=args.epochs)
    edges = bins_mod.make_edges(pcfg.n_bins, pcfg.bin_max)
    target = targets_mod.dist_target(jnp.asarray(data.len_train, jnp.float32), edges)
    pred = train_predictor(jax.random.PRNGKey(args.seed),
                           jnp.asarray(data.phi_train["last"]), target, pcfg, edges)
    reqs = workload_from_scenario(data, args.n_requests, seed=args.seed,
                                  arrival_rate=args.arrival_rate)
    print(f"scenario={args.model_tag}/{args.scenario} requests={len(reqs)} "
          f"noise_radius={noise_radius(data.len_test):.1f}")
    rows = []
    for policy in (
        Policy("fcfs", "max", max_seq_len=args.max_seq),
        Policy("fcfs", "quantile", max_seq_len=args.max_seq),
        Policy("sjf_pred", "quantile", max_seq_len=args.max_seq),
        Policy("sjf_oracle", "oracle", max_seq_len=args.max_seq),
    ):
        eng = SimEngine(args.slots, args.kv_budget, policy, predictor=pred)
        st = eng.run(reqs)
        rows.append(st.row())
        print(f"{st.policy:22s} mean_lat={st.mean_latency:9.1f} "
              f"p90={st.p90_latency:9.1f} thr={st.throughput:6.2f} "
              f"waste={st.kv_waste_ratio:.3f} overflow={st.overflow_events}")
    return rows


def run_real(args):
    from repro.data.tokenizer import ToyTokenizer, make_corpus, N_TOPICS
    cfg = get_config("tiny-lm").with_overrides(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # (serving demo uses an untrained or checkpoint-loaded tiny LM)
    if args.ckpt:
        from repro.training.checkpoint import restore_checkpoint
        import jax.numpy as jnp
        tree = restore_checkpoint(args.ckpt, {"params": params})
        params = tree["params"]
    eng = RealEngine(model, params, max_new=args.max_new)
    rng = np.random.default_rng(args.seed)
    tok = ToyTokenizer()
    n = args.n_requests
    prompts = np.zeros((n, 8), np.int32)
    for i in range(n):
        prompts[i, :6] = tok.prompt(rng, int(rng.integers(0, N_TOPICS)))[:6]
    plens = np.full(n, 6)
    lens, phi = eng.repeated_sampling(prompts, plens, r=args.r, seed=args.seed)
    print(f"collected {lens.shape} generations; median lengths "
          f"{np.median(lens, axis=1)[:8]}")
    nr = noise_radius(jnp.asarray(lens))
    pcfg = PredictorConfig(n_bins=32, bin_max=float(lens.max() + 8), epochs=40)
    edges = bins_mod.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = targets_mod.dist_target(jnp.asarray(lens, jnp.float32), edges)
    split = n // 2
    pred = train_predictor(jax.random.PRNGKey(1), jnp.asarray(phi[:split]),
                           tgt[:split], pcfg, edges)
    est = pred.predict(jnp.asarray(phi[split:]))
    true_med = np.median(lens[split:], axis=1)
    print(f"ProD-D on real generations: test MAE {mae(est, jnp.asarray(true_med)):.2f} "
          f"(noise radius {nr:.2f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["sim", "real"], default="sim")
    ap.add_argument("--model-tag", default="qwen", choices=["qwen", "llama"])
    ap.add_argument("--scenario", default="chat")
    ap.add_argument("--n-requests", type=int, default=200)
    ap.add_argument("--n-train", type=int, default=800)
    ap.add_argument("--arrival-rate", type=float, default=2.0)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--kv-budget", type=int, default=40_000)
    ap.add_argument("--max-seq", type=int, default=4096)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--r", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.mode == "sim":
        run_sim(args)
    else:
        run_real(args)


if __name__ == "__main__":
    main()
