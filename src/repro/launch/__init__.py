"""Launch layer: production meshes, workload input specs, multi-pod dry-run,
and the train/serve entry points."""
