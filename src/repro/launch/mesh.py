"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — critical because the dry-run must set
``xla_force_host_platform_device_count`` *before* first jax init, while smoke
tests must see the 1-CPU default.

Target hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
16 GiB HBM per chip; 256 chips (16×16) per pod, 2 pods via DCN/ICI.
"""

from __future__ import annotations

import jax

# v5e hardware constants (roofline terms)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_BYTES = 16 * (1 << 30)      # per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (unit tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def n_chips(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
