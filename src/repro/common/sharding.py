"""Logical-axis sharding rules (flax-partitioning style, dependency-free).

Every parameter/activation is annotated with a tuple of *logical* axis names
(e.g. ``("embed", "heads", "head_dim")``). A rules table maps logical names to
mesh axes. :func:`resolve_spec` applies the table with two safety fallbacks:

* a dimension whose size is not divisible by the mapped mesh-axis product is
  replicated instead (this is how GQA kv-heads < model-axis-size, batch=1
  long-context decode, and remainder layers degrade gracefully);
* a mesh axis is never used twice within one PartitionSpec (first dim wins).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...]]
LogicalAxes = Tuple[Optional[str], ...]


def default_rules(mesh: Mesh, *, context_parallel: bool = False) -> Dict[str, AxisName]:
    """Logical-name -> mesh-axis table for the production meshes."""
    data_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data: AxisName = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    rules: Dict[str, AxisName] = {
        # activations
        "batch": data,
        "seq": None,
        "act_embed": None,
        # weights — tensor-parallel over `model`; replicated over `data`.
        # (Sharding the d_model dim of weights over `data` makes GSPMD pick
        # contraction-dim-sharded matmuls with full-batch activation
        # all-reduces — measured 40× FLOP/byte inflation in the dry-run.)
        "vocab": "model",
        "embed": None,
        "embed_fsdp": data,      # MoE expert weights: too big to replicate —
                                 # stored d-sharded, explicitly all-gathered
                                 # inside the expert-parallel shard_map
        "opt_embed": data,       # optimizer moments: ZeRO — 256-way sharded
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "experts": "model",
        "expert_ffn": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "ssm_inner": "model",
        "conv": None,
        "pred_hidden": "model",
        "bins": None,
        # kv cache
        "cache_seq": ("data" if context_parallel else None),
        "cache_kv_heads": "model",
        # scan-stacked layer axis
        "layers": None,
        "stats": None,
    }
    return rules


def resolve_spec(
    axes: LogicalAxes,
    shape: Sequence[int],
    mesh: Mesh,
    rules: Dict[str, AxisName],
) -> P:
    """Map logical axes to a PartitionSpec honoring divisibility + axis reuse."""
    assert len(axes) == len(shape), f"axes {axes} vs shape {tuple(shape)}"
    used: set = set()
    out = []
    for name, dim in zip(axes, shape):
        mapped = rules.get(name) if name is not None else None
        if mapped is None:
            out.append(None)
            continue
        axis_tuple = mapped if isinstance(mapped, tuple) else (mapped,)
        axis_tuple = tuple(a for a in axis_tuple if a in mesh.axis_names and a not in used)
        if not axis_tuple:
            out.append(None)
            continue
        prod = int(np.prod([mesh.shape[a] for a in axis_tuple]))
        if prod <= 1 or dim % prod != 0:
            # try progressively shorter prefixes before giving up
            ok = None
            for k in range(len(axis_tuple) - 1, 0, -1):
                sub = axis_tuple[:k]
                p = int(np.prod([mesh.shape[a] for a in sub]))
                if p > 1 and dim % p == 0:
                    ok = sub
                    break
            if ok is None:
                out.append(None)
                continue
            axis_tuple = ok
        used.update(axis_tuple)
        # keep the tuple form whenever the rule mapped to a tuple, even if the
        # divisibility fallback shrank it to one axis — P(("pod",)) and
        # P("pod") shard identically but compare unequal, and downstream code
        # (tests, spec equality against batch_spec) relies on stable form
        out.append(axis_tuple if isinstance(mapped, tuple) else axis_tuple[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(
    axes_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: Optional[Dict[str, AxisName]] = None,
) -> Any:
    """NamedSharding pytree from a logical-axes pytree + shape pytree.

    ``axes_tree`` leaves are tuples of logical names; ``shape_tree`` leaves are
    arrays or ShapeDtypeStructs with matching rank.
    """
    rules = rules if rules is not None else default_rules(mesh)

    def one(axes, arr):
        return NamedSharding(mesh, resolve_spec(tuple(axes), arr.shape, mesh, rules))

    return jax.tree_util.tree_map(
        one, axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def shard_like(axes: LogicalAxes, arr, mesh: Mesh, rules=None) -> NamedSharding:
    rules = rules if rules is not None else default_rules(mesh)
    return NamedSharding(mesh, resolve_spec(axes, arr.shape, mesh, rules))
