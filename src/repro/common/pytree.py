"""Pytree helpers: counting, casting, shape-tree construction."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_shapes(tree: Any) -> Any:
    """Replace every leaf with a ShapeDtypeStruct (for .lower() without allocation)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def tree_zeros_like_spec(tree: Any) -> Any:
    """Materialize zeros from a ShapeDtypeStruct tree (tests only)."""
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def check_finite(tree: Any) -> bool:
    leaves = [
        jnp.all(jnp.isfinite(x))
        for x in jax.tree_util.tree_leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    ]
    if not leaves:
        return True
    return bool(jnp.all(jnp.stack(leaves)))
