"""Kernel-region scoping that survives autodiff.

``jax.named_scope`` metadata is lost on ops produced by transpose/jvp
rewrites, so backward passes of kernel regions would leak into the roofline's
HBM accounting. ``scoped_kernel_vjp`` wraps a region in a ``custom_vjp`` whose
backward re-traces the region *inside* a scope — which is also the faithful
model of the real TPU execution: a Pallas forward kernel plus a
recompute-based backward kernel (flash-attention-style)."""

from __future__ import annotations

import jax


def scoped_kernel_vjp(scope: str, fn):
    """Wrap ``fn(*arrays) -> pytree`` so both passes carry ``scope`` metadata.

    The backward recomputes the forward (checkpoint semantics — exactly what a
    fused attention/SSD backward kernel does on TPU)."""

    @jax.custom_vjp
    def wrapped(*args):
        with jax.named_scope(scope):
            return fn(*args)

    def fwd(*args):
        with jax.named_scope(scope):
            return fn(*args), args

    def bwd(res, g):
        with jax.named_scope(scope + "_bwd"):
            _, vjp = jax.vjp(fn, *res)
            return vjp(g)

    wrapped.defvjp(fwd, bwd)
    return wrapped
