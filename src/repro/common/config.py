"""Configuration system.

Every architecture in ``repro.configs`` produces a :class:`ModelConfig`. The
config is a frozen dataclass so it can be closed over by jitted functions and
hashed as a static argument.

Families
--------
``dense``   decoder-only transformer (GQA/MQA, optional sliding/local-global mix)
``moe``     dense skeleton with MoE FFN every layer (top-k router, expert parallel)
``ssm``     attention-free Mamba2 / SSD stack
``hybrid``  Mamba2 backbone with a shared attention block applied periodically
``encdec``  Whisper-style encoder-decoder (audio frontend stubbed)
``vlm``     decoder LM consuming interleaved text/patch embeddings with M-RoPE
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (the exact assigned values live in repro.configs)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention pattern -------------------------------------------------
    attn_window: int = 0             # 0 = full attention; >0 = sliding window size
    local_global_ratio: int = 0      # gemma3: N local layers per 1 global layer (0=uniform)
    rope_theta: float = 10000.0
    rope_theta_local: float = 10000.0  # gemma3 uses a different base for local layers
    use_mrope: bool = False          # qwen2-vl multimodal RoPE (3 position streams)
    qk_norm: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0        # kimi-k2 style always-on shared expert(s)
    moe_d_ff: int = 0                # per-expert hidden size (0 -> d_ff)
    router_aux_coef: float = 0.01

    # --- SSM (mamba2 / zamba2) ----------------------------------------------
    ssm_state: int = 0               # d_state N
    ssm_heads: int = 0               # number of SSD heads (0 -> derived)
    ssm_head_dim: int = 64           # P
    ssm_chunk: int = 256             # SSD chunk length
    ssm_conv_width: int = 4
    attn_every: int = 0              # hybrid: apply shared attn block every k ssm layers

    # --- enc-dec -----------------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper mel-frame positions after conv stub

    # --- misc --------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                # silu (gated) | gelu (whisper-style plain MLP)
    dtype: str = "bfloat16"
    citation: str = ""

    # --- ProD head (paper core, attached to every arch) ---------------------
    predictor_bins: int = 64
    predictor_hidden: int = 512
    predictor_bin_max: float = 8192.0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "moe" and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.family in ("ssm",), (
            f"{self.name}: n_heads={self.n_heads} not divisible by kv={self.n_kv_heads}"
        )

    # -- derived ------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def ssm_n_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return (2 * self.d_model) // self.ssm_head_dim  # mamba2 default d_inner=2*d

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in the roofline)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

        def ffn_gated(ff):
            return 3 * d * ff

        def ffn_plain(ff):
            return 2 * d * ff

        ffn_fn = ffn_gated if self.act == "silu" else ffn_plain
        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn + ffn_fn(self.d_ff)
            total = L * per_layer
        elif self.family == "moe":
            shared = self.n_shared_experts * ffn_fn(self.moe_d_ff)
            per_layer = attn + self.n_experts * ffn_fn(self.moe_d_ff) + shared + d * self.n_experts
            total = L * per_layer
        elif self.family == "ssm":
            total = L * self._ssm_layer_params()
        elif self.family == "hybrid":
            n_attn = max(1, L // max(self.attn_every, 1)) if self.attn_every else 1
            total = L * self._ssm_layer_params() + (attn + ffn_fn(self.d_ff))  # shared block counted once
            del n_attn
        elif self.family == "encdec":
            enc = self.n_encoder_layers * (attn + ffn_fn(self.d_ff))
            dec = L * (2 * attn + ffn_fn(self.d_ff))  # self + cross attention
            total = enc + dec
        else:
            raise ValueError(self.family)
        return int(total + emb)

    def active_param_count(self) -> int:
        """Activated params per token (MoE uses top-k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn = 3 * d * self.moe_d_ff
        active_layer = attn + (self.n_experts_per_token + self.n_shared_experts) * ffn + d * self.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(L * active_layer + emb)

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        d_inner = self.ssm_n_heads * self.ssm_head_dim
        n = self.ssm_state
        # in_proj (z, x, B, C, dt), conv, A, D, norm, out_proj — mamba2 layout
        return (
            d * (2 * d_inner + 2 * self.ssm_state_groups() * n + self.ssm_n_heads)
            + d_inner * self.ssm_conv_width
            + 2 * self.ssm_n_heads
            + d_inner * d
        )

    def ssm_state_groups(self) -> int:
        return 1

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        while heads % kv:
            kv -= 1
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 512) or self.d_ff,
            vocab_size=min(self.vocab_size, 512),
            encoder_seq=min(self.encoder_seq, 32),
        )
        if self.family == "moe":
            kw.update(n_experts=4, n_experts_per_token=2, moe_d_ff=128,
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_heads=4, ssm_head_dim=32,
                      ssm_chunk=16)
        if self.family == "hybrid":
            kw.update(attn_every=1)
        if self.n_encoder_layers:
            kw.update(n_encoder_layers=2)
        if self.attn_window:
            kw.update(attn_window=min(self.attn_window, 16))
        if self.local_global_ratio:
            kw.update(local_global_ratio=min(self.local_global_ratio, 1))
        return self.with_overrides(**kw)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)


def get_input_shape(name: str) -> InputShape:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; options: {[s.name for s in INPUT_SHAPES]}")


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    schedule: str = "cosine"          # cosine | wsd | constant
    warmup_steps: int = 100
    decay_steps: int = 10_000
    stable_steps: int = 0             # WSD plateau
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0               # 0 = no gradient accumulation
    remat: str = "full"               # none | full | dots
    seed: int = 0


@dataclass(frozen=True)
class PredictorConfig:
    """ProD head + supervision protocol (paper §2.4 / A.2)."""

    n_bins: int = 64
    hidden: int = 512
    bin_max: float = 8192.0
    bin_spacing: str = "linear"       # linear | log (log is a beyond-paper option)
    r_samples: int = 16               # repeated-sampling budget r
    target: str = "median"            # median (ProD-M) | dist (ProD-D) | single
    decode: str = "median"            # median | argmax | mean
    lr: float = 1e-3
    epochs: int = 30
    batch_size: int = 256
    weight_decay: float = 0.0
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    max_batch_slots: int = 32
    max_seq_len: int = 4096
    scheduler: str = "fcfs"           # fcfs | sjf_pred | sjf_oracle | quantile
    reserve_quantile: float = 0.9     # KV reservation quantile from ProD-D
    kv_memory_budget: int = 1 << 24   # tokens of KV the device pool can hold
    decode_temperature: float = 0.8
    seed: int = 0
