"""Common utilities: configuration, sharding rules, pytree helpers."""

from repro.common.config import (  # noqa: F401
    ModelConfig,
    TrainConfig,
    ServeConfig,
    PredictorConfig,
    InputShape,
    INPUT_SHAPES,
)
