"""Continuous-batching engines.

* :class:`SimEngine` — discrete-event simulator (Track A): slot-based
  continuous batching, KV reservation accounting, pluggable scheduler.
  One engine step == one decode step for every active slot (the TPU-idiomatic
  fixed-shape batching model). Used to quantify what better length prediction
  buys in throughput/latency/memory.

* :class:`RealEngine` — Track B: actually decodes a tiny JAX LM with
  temperature sampling, slot-based batching, real KV caches, and the fused
  ProD head on real last-token hidden states.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.kvcache import KVCacheManager
from repro.serving.request import Request
from repro.serving.scheduler import (Policy, annotate_predictions, pick_next,
                                     predicted_remaining)


@dataclass
class ServeStats:
    policy: str
    makespan: float
    mean_latency: float
    p90_latency: float
    mean_wait: float
    throughput: float              # completed tokens / step
    kv_waste_ratio: float
    overflow_events: int
    peak_reserved: int
    completed: int
    preemptions: int = 0

    def row(self) -> dict:
        return self.__dict__.copy()


class SimEngine:
    """Discrete-event continuous-batching simulator."""

    def __init__(self, max_slots: int, kv_budget: int, policy: Policy,
                 predictor=None):
        self.max_slots = max_slots
        self.policy = policy
        self.predictor = predictor
        self.kv = KVCacheManager(budget_tokens=kv_budget)

    def run(self, requests: List[Request], max_steps: int = 1_000_000) -> ServeStats:
        reqs = [Request(**{**r.__dict__}) for r in requests]  # defensive copy
        annotate_predictions(reqs, self.predictor, self.policy)
        queue: List[Request] = sorted(reqs, key=lambda r: r.arrival)
        active: List[Request] = []
        done: List[Request] = []
        t = 0.0
        preemptions = 0
        while (queue or active) and t < max_steps:
            # admit while there is a slot + KV budget
            while len(active) < self.max_slots:
                i = pick_next(queue, self.policy, t)
                if i is None:
                    break
                cand = queue[i]
                need = int(cand.prompt_len + cand.reserve_len)
                if not self.kv.admit(cand.rid, need):
                    break  # KV-bound: head-of-line blocks on memory
                queue.pop(i)
                if cand.t_start is None:
                    cand.t_start = t
                self.kv.use(cand.rid, cand.prompt_len + cand.generated)
                active.append(cand)
            # SRTF preemption: a waiting request with much shorter predicted
            # remaining evicts the longest-remaining active one (ProD-O's
            # remaining-length signal makes this decision possible)
            if self.policy.preempt and active:
                i = pick_next(queue, self.policy, t)
                if i is not None:
                    newcomer = queue[i]
                    victim = max(active, key=predicted_remaining)
                    if (predicted_remaining(victim)
                            > self.policy.preempt_factor
                            * predicted_remaining(newcomer)):
                        active.remove(victim)
                        self.kv.release(victim.rid)
                        queue.append(victim)   # resumes later with progress kept
                        preemptions += 1
            # one decode step for all active slots
            t += 1.0
            for r in list(active):
                r.generated += 1
                self.kv.use(r.rid, 1)
                used = r.prompt_len + r.generated
                if used > int(r.prompt_len + r.reserve_len):
                    # outgrew reservation: grow or stall (overflow penalty)
                    if not self.kv.grow(r.rid, max(int(0.25 * r.reserve_len), 16)):
                        continue  # stalled this step, retries next step
                    r.overflows += 1
                    r.reserve_len *= 1.25
                if r.generated >= r.true_len:
                    r.t_finish = t
                    self.kv.release(r.rid)
                    active.remove(r)
                    done.append(r)
            self.kv.tick()
            if not active and queue:
                nxt = min(q.arrival for q in queue)
                t = max(t, float(np.floor(nxt)))
        lat = np.array([r.latency for r in done])
        waits = np.array([r.wait for r in done])
        toks = sum(r.true_len for r in done)
        return ServeStats(
            policy=f"{self.policy.order}+{self.policy.reserve}",
            makespan=t,
            mean_latency=float(lat.mean()) if len(lat) else float("inf"),
            p90_latency=float(np.quantile(lat, 0.9)) if len(lat) else float("inf"),
            mean_wait=float(waits.mean()) if len(waits) else float("inf"),
            throughput=toks / max(t, 1.0),
            kv_waste_ratio=self.kv.waste_ratio,
            overflow_events=self.kv.overflow_events,
            peak_reserved=self.kv.peak_reserved,
            completed=len(done),
            preemptions=preemptions,
        )


# ---------------------------------------------------------------------------
# Track B: real generation with a tiny JAX LM
# ---------------------------------------------------------------------------


class RealEngine:
    """Batched sampling engine over a real (tiny) model: prefill once, decode
    until EOS, harvest last-token hidden states for the ProD predictor."""

    def __init__(self, model, params, rt=None, temperature: float = 0.8,
                 max_new: int = 256, eos_id: int = 2):
        import jax
        import jax.numpy as jnp
        from repro.models.model_zoo import Runtime

        self.model = model
        self.params = params
        self.rt = rt or Runtime.local()
        self.temp = temperature
        self.max_new = max_new
        self.eos = eos_id
        self._jit_prefill = jax.jit(
            lambda p, b: model.prefill(p, b, self.rt)
        )
        self._jit_decode = jax.jit(
            lambda p, b, c: model.decode_step(p, b, c, self.rt)
        )

    def generate(self, prompts: np.ndarray, prompt_lens: np.ndarray, key,
                 collect_hidden: bool = True, collect_per_step: bool = False):
        """prompts: (B, Sp) right-padded. Returns dict with lengths (B,),
        phi (B, d) last-prompt-token hidden, tokens (B, max_new), and —
        with ``collect_per_step`` — step_hidden (B, max_new, d) + step_valid
        (B, max_new), the per-decode-step states φ(z_t) for the online
        remaining-length predictor (paper §2.2's general t>0 case)."""
        import jax
        import jax.numpy as jnp
        from repro.models.model_zoo import last_token_hidden

        B, Sp = prompts.shape
        cfg = self.model.cfg
        valid = np.arange(Sp)[None, :] < prompt_lens[:, None]
        batch = {"tokens": jnp.asarray(prompts),
                 "attn_valid": jnp.asarray(valid)}
        logits, hidden, cache, _ = self._jit_prefill(self.params, batch)
        phi = last_token_hidden(hidden, jnp.asarray(prompt_lens)) if collect_hidden else None

        # move prefill cache into a decode cache with room for max_new tokens
        cache = self._grow_cache(cache, Sp + self.max_new, Sp)
        lengths = jnp.asarray(prompt_lens, jnp.int32)
        last_logit = logits[jnp.arange(B), lengths - 1]
        finished = jnp.zeros(B, bool)
        out_tokens = np.zeros((B, self.max_new), np.int32)
        gen_len = np.zeros(B, np.int64)
        step_hidden = (np.zeros((B, self.max_new, cfg.d_model), np.float32)
                       if collect_per_step else None)
        step_valid = (np.zeros((B, self.max_new), bool)
                      if collect_per_step else None)
        cur_logits = last_logit
        for step in range(self.max_new):
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, cur_logits / self.temp, axis=-1)
            nxt = jnp.where(finished, self.eos, nxt).astype(jnp.int32)
            out_tokens[:, step] = np.asarray(nxt)
            newly = (~finished) & (nxt == self.eos)
            finished = finished | (nxt == self.eos)
            gen_len = np.where(np.asarray(newly), step + 1, gen_len)
            if bool(finished.all()):
                break
            dbatch = {"tokens": nxt, "pos": lengths, "lengths": lengths + 1}
            cur_logits, hid_t, cache = self._jit_decode(self.params, dbatch, cache)
            if collect_per_step:
                step_hidden[:, step] = np.asarray(hid_t, np.float32)
                step_valid[:, step] = ~np.asarray(finished)
            lengths = lengths + jnp.where(finished, 0, 1)
        gen_len = np.where(gen_len == 0, self.max_new, gen_len)
        return {"lengths": gen_len, "phi": np.asarray(phi) if phi is not None else None,
                "tokens": out_tokens, "step_hidden": step_hidden,
                "step_valid": step_valid}

    def _grow_cache(self, cache, new_len: int, old_len: int):
        import jax.numpy as jnp
        import jax.tree_util as jtu

        def grow(x):
            # attention caches: (..., S, KV, hd) with S == old_len (ring caches
            # are allocated at their window and left alone)
            if x.ndim >= 4 and x.shape[-3] == old_len:
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, new_len - old_len)
                return jnp.pad(x, pad)
            return x

        return jtu.tree_map(grow, cache)

    def repeated_sampling(self, prompts: np.ndarray, prompt_lens: np.ndarray,
                          r: int, seed: int = 0):
        """The paper's data-collection loop: r independent generations per
        prompt. Returns (lengths (B, r), phi (B, d))."""
        import jax

        B = prompts.shape[0]
        lens = np.zeros((B, r), np.int64)
        phi = None
        for j in range(r):
            out = self.generate(prompts, prompt_lens, jax.random.PRNGKey(seed * 997 + j),
                                collect_hidden=(j == 0))
            lens[:, j] = out["lengths"]
            if j == 0:
                phi = out["phi"]
        return lens, phi
