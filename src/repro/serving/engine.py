"""Continuous-batching engines.

* :class:`SimEngine` — discrete-event simulator (Track A): slot-based
  continuous batching, KV reservation accounting, pluggable scheduler.
  One engine step == one decode step for every active slot (the TPU-idiomatic
  fixed-shape batching model). Used to quantify what better length prediction
  buys in throughput/latency/memory.

  A replica's capacity is a :class:`ReplicaSpec`: slot count, KV budget, an
  integer decode-speed multiplier (tokens emitted per slot per step — a
  faster accelerator), and a prefill rate (``prefill_tokens_per_step``; an
  admitted slot spends ``ceil(prompt_tokens / rate)`` ticks prefilling before
  its first decode token, 0 = prefill is free). Requests may carry a
  ``deadline``: queue entries whose deadline has passed — including
  preempted requests waiting to resume — are dropped (``timed_out``) when
  they reach the head of the ready queue, and requests finishing past their
  deadline count as ``slo_violations``.

  The engine is *stepwise*: :meth:`submit` enqueues requests, :meth:`step`
  advances one decode tick, so a :class:`~repro.serving.cluster.Cluster` can
  drive N replicas in lockstep against a shared clock. :meth:`run` wraps the
  closed-loop single-replica flow. The per-tick decode comes in two
  implementations — a per-slot reference loop and a vectorized NumPy fast
  path over the slot arrays (default) — that produce bit-identical results;
  the fast path is what lets a 50k-request trace replay in seconds.

* :class:`RealEngine` — Track B: actually decodes a tiny JAX LM with
  temperature sampling, slot-based batching, real KV caches, and the fused
  ProD head on real last-token hidden states.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.kvcache import KVCacheManager
from repro.serving.request import Request
from repro.serving.scheduler import (Policy, annotate_predictions, order_key,
                                     predicted_remaining, quantile_remaining)
# shared percentile summarization lives in telemetry (one implementation for
# ServeStats and ClusterStats); the underscore aliases keep the historical
# engine-module import surface working
from repro.serving.telemetry import goodput as _goodput
from repro.serving.telemetry import latency_summary as _latency_stats
from repro.serving.telemetry import ttft_summary as _ttft_stats


@dataclass(frozen=True)
class ReplicaSpec:
    """Per-replica capacity: what a heterogeneous cluster varies.

    Parameters
    ----------
    max_slots : concurrent decode slots (continuous-batching width).
    kv_budget : KV-cache pool size in tokens; reservations draw from it.
    speed : integer decode multiplier — every active (non-prefilling) slot
        emits ``speed`` tokens per engine step (a faster accelerator).
    prefill_tokens_per_step : prompt tokens one prefill tick processes; an
        admitted slot spends ``ceil(prompt / rate)`` ticks prefilling before
        its first decode token. 0 keeps the legacy free-prefill model.
    page_size : KV-cache page granularity in tokens; reservations are whole
        pages (``kv_budget`` must be page-aligned). 1 reproduces the scalar
        token counter bit-exactly.
    share_prefixes : back requests' declared common contexts
        (``Request.prefix_id``/``prefix_len``) with ref-counted shared KV
        pages + copy-on-write instead of private copies, and skip their
        prefill. Off (the default) is bit-identical to a non-sharing pool.
    step_token_budget : vLLM-style per-step token budget — the total tokens
        (prefill chunk tokens + decode tokens) one engine tick may process.
        Prefilling slots consume their prompt in chunks drawn from this
        budget, interleaved with decode: decode slots emit fewer tokens on
        ticks where prefill spends the budget (``Policy.chunk_order`` picks
        which prefilling slot feeds first). ``None`` (the default) keeps the
        tick-based ``prefill_tokens_per_step`` model bit-identically.
    prefill_chunk_tokens : budget mode only — cap on the prefill tokens one
        slot may draw from the budget per tick. 0 means *atomic* prefill
        under the budget: a tick with any prefilling slot dedicates the
        whole budget to prefill (decode pauses), the non-chunked serving
        model chunked prefill exists to beat.
    """
    max_slots: int
    kv_budget: int
    speed: int = 1
    prefill_tokens_per_step: int = 0
    page_size: int = 1
    share_prefixes: bool = False
    step_token_budget: Optional[int] = None
    prefill_chunk_tokens: int = 0

    def __post_init__(self):
        if self.max_slots <= 0 or self.kv_budget <= 0:
            raise ValueError("max_slots and kv_budget must be positive")
        if int(self.speed) != self.speed or self.speed < 1:
            raise ValueError(f"speed must be a positive integer, got {self.speed}")
        if self.prefill_tokens_per_step < 0:
            raise ValueError("prefill_tokens_per_step must be >= 0")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.kv_budget % self.page_size:
            raise ValueError("kv_budget must be a multiple of page_size")
        if self.step_token_budget is not None:
            if self.step_token_budget < 1:
                raise ValueError("step_token_budget must be >= 1")
            if self.prefill_tokens_per_step:
                raise ValueError(
                    "step_token_budget and prefill_tokens_per_step are "
                    "mutually exclusive prefill cost models")
        if self.prefill_chunk_tokens < 0:
            raise ValueError("prefill_chunk_tokens must be >= 0")
        if self.prefill_chunk_tokens and self.step_token_budget is None:
            raise ValueError(
                "prefill_chunk_tokens needs step_token_budget (chunked "
                "prefill is a budget-mode knob)")

    @property
    def service_rate(self) -> float:
        """Decode tokens per step at full occupancy (the router's view of
        how fast this replica drains work)."""
        return float(self.max_slots * self.speed)


@dataclass
class ServeStats:
    policy: str
    makespan: float
    mean_latency: float
    p50_latency: float
    p90_latency: float
    p99_latency: float
    mean_wait: float
    throughput: float              # completed tokens / step
    kv_waste_ratio: float
    overflow_events: int
    # per-replica pool high-water mark; fleet-level pressure is reported as
    # occupancy, and replica_rows keeps the per-replica peaks
    peak_reserved: int  # reprolint: disable=stats-cluster-parity
    completed: int
    preemptions: int = 0
    oom_evictions: int = 0
    dropped: int = 0               # unservable: need exceeds the whole pool
    # deadline passed while queued (incl. preempted requests awaiting resume)
    timed_out: int = 0
    slo_violations: int = 0        # completed, but past the deadline
    goodput: float = 0.0           # within-SLO completed tokens / step
    # paged-KV accounting (page_size=1 ⇒ occupancy of the scalar pool,
    # frag_ratio == 0, and the held_* columns are 0 unless preempt_mode="keep")
    # replica identity, not a counter: a heterogeneous fleet has no single
    # page size — replica_rows carries the per-replica values
    page_size: int = 1  # reprolint: disable=stats-cluster-parity
    occupancy: float = 0.0         # mean reserved fraction of the pool
    frag_ratio: float = 0.0        # page-rounding slack / reserved integral
    held_peak: int = 0             # peak tokens held by preempted waiters
    held_steps: float = 0.0        # token-steps held while preempted-queued
    held_releases: int = 0         # held pages dropped to break memory stalls
    recompute_ticks: int = 0       # prefill ticks re-paid for preempted work
    # prefix sharing (all inert unless share_prefixes=True + tagged requests)
    kv_amplification: float = 1.0  # logical / physical reserved token-steps
    prefix_hits: int = 0           # admissions that reused shared pages
    cow_copies: int = 0            # divergence-boundary pages privatized
    prefix_evictions: int = 0      # cached prefixes reclaimed under pressure
    shared_peak: int = 0           # peak tokens in live shared pages
    prefill_ticks: int = 0         # prefill ticks actually paid
    prefill_saved_ticks: int = 0   # prefill ticks erased by prefix hits
    # posterior refinement (all 0 unless Policy.refine_every > 0)
    refine_events: int = 0         # active-slot quantile refreshes applied
    refine_shrinks: int = 0        # re-reservations that released pages
    refine_grows: int = 0          # re-reservations that drew new pages
    # time-to-first-token percentiles (t_first_token − arrival, over
    # completed requests that emitted at least one token; inf when none did)
    mean_ttft: float = float("inf")
    p50_ttft: float = float("inf")
    p90_ttft: float = float("inf")
    p99_ttft: float = float("inf")

    def row(self) -> dict:
        return self.__dict__.copy()


class SimEngine:
    """Discrete-event continuous-batching simulator (one replica).

    Scheduling semantics per :meth:`step`:

    1. *admit*: drop expired queue heads (``timed_out``), then pop ready
       requests in policy order — FCFS/SJF/SRTF or the deadline-aware EDF /
       least-laxity orderings (see :mod:`repro.serving.scheduler`) — while a
       slot and KV reservation budget are available (head-of-line blocks on
       memory). An admitted slot first spends its prefill ticks (see
       :class:`ReplicaSpec`) emitting nothing;
    2. *preempt* (SRTF policies): the ready request with the shortest
       predicted remaining length evicts the longest-remaining active slot
       when the gap exceeds ``preempt_factor`` (progress is kept). Under
       ``Policy.preempt_mode="recompute"`` the victim's whole reservation is
       released and resume re-reserves — and re-prefills — from scratch;
       under ``"keep"`` the victim shrinks its reservation to the pages it
       has already filled and *holds* them while queued, so resume reserves
       only the delta pages and skips the prefill recompute (a victim still
       in prefill always recomputes — its pages hold no finished work yet);
    3. *decode*: every active non-prefilling slot emits ``spec.speed``
       tokens. A slot that would outgrow its reservation first grows it by
       max(25%, 16, speed) tokens; if the budget refuses, the slot emits
       only what fits (possibly nothing) this tick and retries next tick.

    Held pages count toward the reservation integral but not the usage one:
    the waste/occupancy metrics price exactly the memory that keep-mode
    preemption pins while its owner waits. When every slot is idle or
    stalled *because* queued holders pin the pool, the engine releases held
    pages (largest-queue-key holders first — ``held_releases``), reverting
    those requests to recompute semantics rather than deadlocking.
    """

    def __init__(self, max_slots: Optional[int] = None,
                 kv_budget: Optional[int] = None,
                 policy: Optional[Policy] = None, predictor=None,
                 vectorized: bool = True, spec: Optional[ReplicaSpec] = None,
                 refiner=None, tracer=None):
        if spec is None:
            if max_slots is None or kv_budget is None:
                raise ValueError(
                    "SimEngine needs either spec=ReplicaSpec(...) or both "
                    "max_slots and kv_budget")
            spec = ReplicaSpec(max_slots=max_slots, kv_budget=kv_budget)
        if policy is None:
            raise ValueError("SimEngine needs a scheduling policy")
        self.spec = spec
        self.max_slots = spec.max_slots
        self.policy = policy
        self.predictor = predictor
        self.vectorized = vectorized
        # posterior refinement (Policy.refine_every > 0): every refine tick
        # the engine re-conditions active-slot histograms on decode progress
        # via this PosteriorRefiner; 0 keeps every legacy path bit-identical
        # (the refiner, if passed, is never consulted)
        self.refiner = refiner
        self._refine_every = int(policy.refine_every)
        if self._refine_every > 0 and refiner is None:
            raise ValueError(
                "Policy.refine_every > 0 needs a PosteriorRefiner over the "
                "predictor's bin edges (pass refiner=... to the engine)")
        self._kv_budget = spec.kv_budget
        # step-token-budget mode: None keeps every legacy path bit-identical
        self._budget = spec.step_token_budget
        # effective per-slot prefill chunk: the explicit cap, else the whole
        # budget; _atomic marks the non-chunked model (prefill ticks dedicate
        # the entire budget to prefill and decode pauses)
        self._chunk = min(spec.prefill_chunk_tokens or (spec.step_token_budget
                                                        or 0),
                          spec.step_token_budget or 0)
        self._atomic = spec.prefill_chunk_tokens == 0
        # optional telemetry (repro.serving.telemetry.Tracer): every hook is
        # an `if tracer is not None` read-only branch, so tracer=None stays
        # bit-identical to a tracer-less build (golden-pinned). Gauge sample
        # ticks are evented (like refine ticks), so both decode paths sample
        # identical state at identical ticks.
        self.tracer = tracer
        self.replica_id = 0     # a Cluster labels its engines 0..N-1
        self._sample_every = int(tracer.sample_every) \
            if tracer is not None else 0
        self.reset()

    # -- lifecycle -----------------------------------------------------------

    def reset(self):
        self.kv = KVCacheManager(budget_tokens=self._kv_budget,
                                 page_size=self.spec.page_size,
                                 share_prefixes=self.spec.share_prefixes)
        self.t = 0.0
        self.preemptions = 0
        self.oom_evictions = 0
        self.dropped = 0
        self.timed_out = 0
        self.slo_violations = 0
        self.recompute_ticks = 0
        self.prefill_ticks = 0
        self.prefill_saved_ticks = 0
        self.held_releases = 0
        self.refine_events = 0
        self.refine_shrinks = 0
        self.refine_grows = 0
        # next tick whose start crosses the refine schedule (multiples of
        # refine_every); kept a pure function of t so both decode paths and
        # idle skips land on identical refine ticks
        self._next_refine = float(self._refine_every) if self._refine_every \
            else np.inf
        # next gauge-sample tick (pure function of t, like _next_refine)
        self._next_sample = float(self._sample_every) if self._sample_every \
            else np.inf
        self._held_tokens = 0       # Σ tokens held by preempted waiters here
        self._held_ready = 0        # the ready-queue (releasable) part
        self._held_peak = 0
        self._held_steps = 0.0
        self._progress = True       # did the last decode tick advance any slot?
        self._seq = 0                       # heap tie-break, FIFO among ties
        self._future: list = []             # (due tick, seq, Request)
        self._future_need = 0               # Σ future reservation needs
        self._future_pred = 0.0             # Σ future predicted remaining
        self._ready: list = []              # (policy key, seq, Request)
        self._ready_need = 0                # Σ queued reservation needs
        self._ready_pred = 0.0              # Σ queued predicted remaining
        self._slots: List[Request] = []     # active, admission order
        self._n_active = 0
        m = self.max_slots
        self._a_gen = np.zeros(m, np.int64)
        self._a_used = np.zeros(m, np.int64)
        self._a_res = np.zeros(m, np.int64)
        self._a_plen = np.zeros(m, np.int64)
        self._a_tlen = np.zeros(m, np.int64)
        self._a_pref = np.zeros(m, np.int64)    # remaining prefill ticks
        self._a_pftok = np.zeros(m, np.int64)   # remaining prefill tokens
        #                                         (step_token_budget mode)
        self._a_pred = np.zeros(m, np.float64)
        self._a_shared = np.zeros(m, np.int64)  # grant tokens on shared pages
        # Σ physical used tokens of active slots: each slot's (used − shared)
        # — shared-page content is integrated once via kv.shared_now instead
        # of once per referencing slot. Sharing off ⇒ plain Σ used.
        self._used_sum = 0
        self._done: List[Request] = []
        self._timed_out: List[Request] = []

    # -- queue ---------------------------------------------------------------

    def _order_key(self, r: Request) -> float:
        # max_cap lets quantile_remaining spot an uninformative reserve="max"
        # reservation and fall through to the point prediction; the refiner
        # (refinement enabled only) keeps over-runner keys well-defined
        return order_key(r, self.policy.order,
                         max_cap=float(self.policy.max_seq_len),
                         refiner=self.refiner if self._refine_every else None)

    @staticmethod
    def _queue_need(r: Request) -> int:
        """Incremental KV a queued request still needs to start: its full
        reservation, minus the pages a keep-mode preemption left it holding
        (those already sit in ``kv.reserved_now``, so counting them again
        would double-bill every router/steal/admission signal)."""
        return max(0, int(r.prompt_len + r.reserve_len) - r.held)

    def _push_ready(self, r: Request):
        self._seq += 1
        heapq.heappush(self._ready, (self._order_key(r), self._seq, r))
        self._ready_need += self._queue_need(r)
        self._ready_pred += predicted_remaining(r)
        self._held_ready += r.held

    def _forget_ready(self, r: Request):
        """Undo _push_ready's aggregate accounting for a departing entry."""
        self._ready_need -= self._queue_need(r)
        self._ready_pred -= predicted_remaining(r)
        self._held_ready -= r.held

    def _pop_ready(self) -> Request:
        _, _, r = heapq.heappop(self._ready)
        self._forget_ready(r)
        return r

    def submit(self, requests: List[Request], after: Optional[float] = None):
        """Enqueue requests (already annotated with predictions/reservations).
        Requests with a future arrival wait in the arrival heap. ``after``
        holds entries back until that tick even if they already arrived —
        the work-stealing migration delay (KV pages / prompt re-transfer):
        a stolen request becomes runnable on the thief only at
        ``max(arrival, after)``, while latency still counts from ``arrival``.
        """
        for r in requests:
            due = float(r.arrival) if after is None \
                else max(float(r.arrival), float(after))
            if due > self.t:
                self._seq += 1
                heapq.heappush(self._future, (due, self._seq, r))
                self._future_need += self._queue_need(r)
                self._future_pred += predicted_remaining(r)
            else:
                self._push_ready(r)

    @property
    def idle(self) -> bool:
        return not (self._n_active or self._ready or self._future)

    @property
    def done(self) -> List[Request]:
        return self._done

    @property
    def timed_out_requests(self) -> List[Request]:
        return self._timed_out

    # -- router signals (cluster dispatch) -----------------------------------
    # these count the future heap too: in cluster use it holds exactly the
    # in-transit stolen requests (steal_cost migration delay) — work already
    # assigned to this replica that load signals must not ignore, or
    # consecutive rebalances would over-steal to the same thief

    @property
    def outstanding_requests(self) -> int:
        return self._n_active + len(self._ready) + len(self._future)

    @property
    def outstanding_kv(self) -> int:
        """Reserved KV of active slots + reservation needs of the queue
        (including in-transit migrations)."""
        return self.kv.reserved_now + self._ready_need + self._future_need

    def predicted_backlog(self) -> float:
        """Predicted remaining decode tokens across active + queued +
        in-transit requests (the ProD signal a predicted-shortest-queue
        router dispatches on)."""
        n = self._n_active
        act = float(np.maximum(self._a_pred[:n] - self._a_gen[:n], 1.0).sum())
        return act + self._ready_pred + self._future_pred

    # -- work stealing (cluster rebalance) -----------------------------------

    def steal_queued(self, k: int, mode: str = "tail",
                     fit: Optional[int] = None,
                     fit_page_size: int = 1) -> List[Request]:
        """Remove up to ``k`` queued (ready, never active) requests so the
        cluster can migrate them to a less-loaded replica.

        ``mode='tail'`` takes the entries the local policy would serve last
        (classic work-stealing deque: the owner pops the head, the thief
        steals the tail). ``mode='quantile'`` is the ProD-aware variant: it
        takes the requests with the largest predicted-quantile remaining work
        (``reserve_len`` − progress), moving the most token-load per steal.
        ``fit`` restricts stealing to requests whose full reservation need
        fits that budget (the thief's KV pool) — a keep-mode holder's kept
        pages migrate with it and are re-reserved out of the thief's pool,
        so its delta need alone would understate feasibility and strand an
        oversized request on a small replica (dropped on arrival). The need
        is rounded up to whole pages of ``fit_page_size`` — the *thief's*
        page granularity, which can be coarser than the donor's: comparing
        raw tokens would pass a request whose page-rounded grant exceeds
        the thief's pool, only for the thief to drop it on arrival.
        """
        if k <= 0 or not self._ready:
            return []
        if mode == "quantile":
            cap = float(self.policy.max_seq_len)
            rz = self.refiner if self._refine_every else None

            def keyf(e):
                return (quantile_remaining(e[2], max_cap=cap, refiner=rz),
                        e[1])
        else:   # 'tail': largest policy key = served last
            keyf = None
        idx = sorted(range(len(self._ready)),
                     key=(lambda i: keyf(self._ready[i])) if keyf
                     else self._ready.__getitem__)
        if fit is not None:
            ps = max(1, int(fit_page_size))

            def rounded_need(r):
                need = int(r.prompt_len + r.reserve_len)
                return -(-need // ps) * ps   # thief's page-rounded grant

            idx = [i for i in idx
                   if rounded_need(self._ready[i][2]) <= fit]
        chosen = idx[len(idx) - min(k, len(idx)):]   # largest keys last
        if not chosen:
            return []
        chosen_set = set(chosen)
        keep = [e for i, e in enumerate(self._ready) if i not in chosen_set]
        out = [self._ready[i][2] for i in chosen]
        self._ready = keep
        heapq.heapify(self._ready)
        for r in out:
            self._forget_ready(r)
        return out

    # -- partial-reservation handoff (keep-mode pages crossing replicas) -----

    def export_held(self, r: Request) -> int:
        """Donor side of a page handoff: the migrating request's kept pages
        leave this replica's pool (their contents travel with the steal).
        Returns the token count that left."""
        held = r.held
        if held:
            self.kv.release(r.rid)
            self._held_tokens -= held
        return held

    def adopt_held(self, r: Request) -> bool:
        """Thief side of a page handoff: re-reserve the migrated pages in
        this pool, re-rounded to this replica's page size (joining this
        pool's copy of the request's prefix, if resident). On failure the
        pages are dropped and the request reverts to recompute semantics
        (progress tokens kept, prefill re-paid)."""
        if not r.held:
            return False
        if self.kv.admit(r.rid, r.held, r.prefix_id,
                         min(int(r.prefix_len), int(r.held))):
            r.held = self.kv.reserved[r.rid]
            self._held_tokens += r.held
            self._held_peak = max(self._held_peak, self._held_tokens)
            return True
        r.held = 0
        return False

    # -- one engine tick -----------------------------------------------------

    @staticmethod
    def _prefix_args(r: Request):
        """The (prefix_id, prefix_len) pair every admission-path KV call must
        pass identically — _admit, the stall breaker, and ticks_to_event —
        or the event leap would disagree with the step about feasibility."""
        return r.prefix_id, min(int(r.prefix_len), int(r.prompt_len))

    def _prefill_ticks(self, r: Request) -> int:
        """Admission cost: ceil(prompt tokens / prefill rate). A resumed
        request that kept its pages (``r.held``) has its prompt + progress
        KV already resident — no recompute. One that lost them recomputes
        prompt + generated progress (vLLM recompute-preemption semantics);
        that whole resume charge is re-work, counted in ``recompute_ticks``.
        Prompt tokens covered by a shared-prefix cache hit are already
        resident too — they are skipped, and the erased ticks are counted in
        ``prefill_saved_ticks``. Call after the KV reservation (the skip is
        recorded at admit)."""
        pts = self.spec.prefill_tokens_per_step
        if pts <= 0:
            return 0
        if r.held > 0:
            return 0
        work = r.prompt_len + r.generated
        full = -(-work // pts)
        skip = min(self.kv.prefill_skip(r.rid), r.prompt_len)
        ticks = -(-(work - skip) // pts) if work > skip else 0
        if r.generated > 0:
            self.recompute_ticks += ticks
        self.prefill_ticks += ticks
        self.prefill_saved_ticks += full - ticks
        return ticks

    def _prefill_tokens(self, r: Request) -> int:
        """Budget-mode admission cost: prompt tokens the slot must pull from
        the step token budget before its first decode token — prompt plus
        recompute progress, minus the shared-prefix skip; a keep-mode holder
        resumes free. The tick-based counters keep their meaning in chunk
        units: ``recompute_ticks``/``prefill_saved_ticks`` are estimated at
        the effective chunk rate here, ``prefill_ticks`` counts the slot-
        ticks the budgeted tick actually spends (call after the KV
        reservation — the skip is recorded at admit)."""
        if r.held > 0:
            return 0
        work = int(r.prompt_len + r.generated)
        skip = min(self.kv.prefill_skip(r.rid), int(r.prompt_len))
        toks = max(work - skip, 0)
        ce = max(self._chunk, 1)
        if r.generated > 0:
            self.recompute_ticks += -(-toks // ce)
        self.prefill_saved_ticks += -(-work // ce) - (-(-toks // ce))
        return toks

    def _expire_ready_head(self):
        """Drop ready-queue heads that can never start here: reservation need
        larger than this replica's entire KV pool (``dropped`` — reachable on
        heterogeneous fleets when routing or stealing lands an oversized
        request on a small replica, and it must not wedge the queue), or
        deadline passed (``timed_out`` — includes preempted requests waiting
        to resume; their progress is discarded). Only the head is checked
        (lazy TTL): entries deeper in the queue are dropped when they
        surface, so router load signals may transiently count them. A
        departing entry's held pages are released here — and only here, when
        it actually times out or proves unservable."""
        while self._ready:
            r = self._ready[0][2]
            need = int(r.prompt_len + r.reserve_len)
            # sharing-aware servability: a raw pages_for(need) > pages_total
            # test would wrongly drop a session follow-up whose resident
            # shared prefix (or kept pages) already covers part of its need
            if not self.kv.servable(r.rid, need, *self._prefix_args(r)):
                self._pop_ready()
                self._drop_held(r)
                self.dropped += 1
                if self.tracer is not None:
                    self.tracer.emit(self.t, self.replica_id, r.rid,
                                     "dropped", need=need)
                continue
            if r.deadline is None or r.deadline >= self.t:
                break
            self._pop_ready()
            self._drop_held(r)
            self.timed_out += 1
            self._timed_out.append(r)
            if self.tracer is not None:
                self.tracer.emit(self.t, self.replica_id, r.rid, "timeout",
                                 deadline=float(r.deadline))

    def _drop_held(self, r: Request):
        """Release the pages a departing (timed-out/dropped/stall-broken)
        holder was keeping. Call after the entry left the ready queue."""
        if r.held:
            self.kv.release(r.rid)
            self._held_tokens -= r.held
            r.held = 0

    def _release_queued_held(self, spare: Optional[Request] = None,
                             need: Optional[int] = None,
                             max_n: Optional[int] = None) -> int:
        """Break a held-pages memory stall: release the pages of ready-queue
        holders — largest (policy key, seq) first, i.e. the entries this
        queue would serve last — reverting them to recompute semantics.
        With ``spare``/``need`` set, stop as soon as ``spare`` fits;
        ``max_n`` caps how many holders are sacrificed per call. Returns how
        many were released."""
        released = 0
        for _, _, r in sorted(self._ready, reverse=True):
            if r.held == 0 or r is spare:
                continue
            before = self._queue_need(r)
            freed = r.held
            self.kv.release(r.rid)
            self._held_tokens -= r.held
            self._held_ready -= r.held
            r.held = 0
            self._ready_need += self._queue_need(r) - before
            self.held_releases += 1
            released += 1
            if self.tracer is not None:
                self.tracer.emit(self.t, self.replica_id, r.rid,
                                 "held_release", tokens=int(freed))
            if max_n is not None and released >= max_n:
                break
            if (spare is not None
                    and self.kv.can_reserve(spare.rid, need,
                                            *self._prefix_args(spare))):
                break
        return released

    def _admit(self):
        while self._future and self._future[0][0] <= self.t:
            _, _, r = heapq.heappop(self._future)
            self._future_need -= self._queue_need(r)
            self._future_pred -= predicted_remaining(r)
            self._push_ready(r)
        self._expire_ready_head()
        while self._n_active < self.max_slots and self._ready:
            _, _, cand = self._ready[0]
            need = int(cand.prompt_len + cand.reserve_len)
            pfx = self._prefix_args(cand)
            if not self.kv.can_reserve(cand.rid, need, *pfx):
                # nothing active to free memory, yet queued holders pin the
                # pool: release their pages (recompute for them) so the head
                # can start — without this, keep mode can wedge the queue
                if not (self._n_active == 0
                        and self._held_ready > cand.held
                        and self._release_queued_held(cand, need)
                        and self.kv.can_reserve(cand.rid, need, *pfx)):
                    break  # KV-bound: head-of-line blocks on memory
            self.kv.reserve(cand.rid, need, *pfx)  # full need (joining the
            self._pop_ready()                      # prefix), delta if holding
            if cand.t_start is None:
                cand.t_start = self.t
            i = self._n_active
            self._slots.append(cand)
            self._a_gen[i] = cand.generated      # preempted resume w/ progress
            self._a_used[i] = cand.prompt_len + cand.generated
            self._a_res[i] = self.kv.reserved[cand.rid]  # page-rounded grant
            self._a_plen[i] = cand.prompt_len
            self._a_tlen[i] = cand.true_len
            if self._budget is None:
                self._a_pref[i] = self._prefill_ticks(cand)
                self._a_pftok[i] = 0
            else:
                self._a_pref[i] = 0
                self._a_pftok[i] = self._prefill_tokens(cand)
            self._a_pred[i] = (cand.predicted_len
                               if cand.predicted_len is not None
                               else float(cand.true_len))
            self._a_shared[i] = self.kv.shared_tokens_of(cand.rid)
            if cand.held:                        # kept pages now active again
                self._held_tokens -= cand.held
                cand.held = 0
            self._used_sum += int(self._a_used[i]) - int(self._a_shared[i])
            self._n_active += 1
            if self.tracer is not None:
                self.tracer.emit(
                    self.t, self.replica_id, cand.rid, "admitted",
                    grant=int(self._a_res[i]),
                    pf=int(self._a_pref[i]) or int(self._a_pftok[i]),
                    resumed=int(cand.generated > 0))
            self._expire_ready_head()

    def _maybe_preempt(self):
        # SRTF preemption: a waiting request with much shorter predicted
        # remaining evicts the longest-remaining active one (ProD-O's
        # remaining-length signal makes this decision possible)
        if not (self.policy.preempt and self._n_active and self._ready):
            return
        newcomer = self._ready[0][2]
        n = self._n_active
        rem = np.maximum(self._a_pred[:n] - self._a_gen[:n], 1.0)
        v = int(np.argmax(rem))
        if rem[v] > self.policy.preempt_factor * predicted_remaining(newcomer):
            victim = self._slots[v]
            victim.generated = int(self._a_gen[v])
            if (self.policy.preempt_mode == "keep" and self._a_pref[v] == 0
                    and self._a_pftok[v] == 0):
                # keep-pages: shrink to the filled pages and hold them, so
                # resume reserves only the delta and skips the prefill
                # recompute. A victim still prefilling has nothing finished
                # in its pages yet, so it always takes the recompute path.
                victim.held = self.kv.shrink(victim.rid, int(self._a_used[v]))
                self._held_tokens += victim.held
                self._held_peak = max(self._held_peak, self._held_tokens)
            else:
                self.kv.release(victim.rid)
            self._used_sum -= int(self._a_used[v]) - int(self._a_shared[v])
            self._drop_slot(v)
            self._push_ready(victim)   # resumes later with progress kept
            self.preemptions += 1
            if self.tracer is not None:
                self.tracer.emit(
                    self.t, self.replica_id, victim.rid, "preempted",
                    kept=int(victim.held),
                    mode="keep" if victim.held else "recompute")

    def _drop_slot(self, i: int):
        """Remove slot i, keeping admission order (stable left shift)."""
        n = self._n_active
        self._slots.pop(i)
        for a in (self._a_gen, self._a_used, self._a_res, self._a_plen,
                  self._a_tlen, self._a_pref, self._a_pftok, self._a_pred,
                  self._a_shared):
            a[i:n - 1] = a[i + 1:n]
        self._n_active = n - 1

    def _finish_slot(self, i: int):
        r = self._slots[i]
        r.t_finish = self.t
        r.generated = int(self._a_gen[i])
        if r.deadline is not None and r.t_finish > r.deadline:
            self.slo_violations += 1
        self.kv.release(r.rid)
        self._used_sum -= int(self._a_used[i]) - int(self._a_shared[i])
        self._drop_slot(i)
        self._done.append(r)
        if self.tracer is not None:
            self.tracer.emit(self.t, self.replica_id, r.rid, "finish",
                             gen=int(r.generated), slo_ok=int(bool(r.slo_met)))
            self.tracer.observe_residual(r)

    def _decode_tick_ref(self):
        """Reference per-slot decode loop (exact sequential semantics)."""
        self._progress = False
        sp = self.spec.speed
        i = 0
        while i < self._n_active:
            if self._a_pref[i] > 0:
                self._a_pref[i] -= 1    # prefill tick: no token emitted
                self._progress = True
                i += 1
                continue
            r = self._slots[i]
            emit = min(sp, int(self._a_tlen[i] - self._a_gen[i]))
            if emit <= 0:
                # degenerate zero-remaining request (true_len == generated,
                # e.g. a directly-constructed true_len=0): finishes without
                # emitting, matching the vectorized finished-mask semantics
                self._progress = True
                self._finish_slot(i)
                continue
            res = int(self._a_res[i])
            head = res - int(self._a_plen[i] + self._a_gen[i])
            if emit > head:
                # outgrew reservation: grow or emit what fits (overflow)
                if self.kv.grow(r.rid, max(int(0.25 * res), 16, sp)):
                    self._a_res[i] = self.kv.reserved[r.rid]
                    r.overflows += 1
                    # the paged grow grants whole pages, which (for
                    # page_size < speed) can still fall short of emit:
                    # re-clamp so a slot never emits past its granted pages
                    head = int(self._a_res[i]) \
                        - int(self._a_plen[i] + self._a_gen[i])
                if emit > head:
                    emit = head     # partial; 0 == stalled this tick
            if emit <= 0:
                i += 1
                continue  # stalled on the reservation, retries next tick
            if r.t_first_token is None:
                r.t_first_token = self.t
                if self.tracer is not None:
                    self.tracer.emit(self.t, self.replica_id, r.rid,
                                     "first_token")
            self._a_gen[i] += emit
            self._a_used[i] += emit
            self._used_sum += emit
            self._progress = True
            if self._a_gen[i] >= self._a_tlen[i]:
                self._finish_slot(i)
            else:
                i += 1
        if self._n_active and not self._progress:
            self._evict_stalled()

    def _evict_stalled(self):
        """KV deadlock breaker: every active slot is stalled on a reservation
        grow the budget cannot satisfy, and (with no completions pending) no
        waiting can change that. Preempt the most recently admitted slot
        (vLLM-style recompute preemption, progress kept) so the freed tokens
        let the remaining slots grow. The victim's reservation ask is bumped
        past its current progress so its re-admission can emit tokens —
        clamped to the pool size so the request stays admittable. A victim
        whose clamped ask buys no headroom needs more KV than the whole pool
        holds: it can never finish under any policy, so it is dropped.

        When queued keep-mode holders pin part of the pool, one holder's
        pages are released per stall tick instead (cheaper: that request
        merely falls back to recompute, and the rest keep their pages);
        eviction retries next tick if decode is still stuck."""
        if self._held_ready > 0 and self._release_queued_held(max_n=1):
            return
        v = self._n_active - 1
        victim = self._slots[v]
        victim.generated = int(self._a_gen[v])
        ask = max(victim.reserve_len * 1.25,
                  victim.generated + float(max(16, self.spec.speed)))
        ask = min(ask, float(self.kv.budget_tokens - victim.prompt_len))
        self.kv.release(victim.rid)
        self._used_sum -= int(self._a_used[v]) - int(self._a_shared[v])
        self._drop_slot(v)
        self.oom_evictions += 1
        if self.tracer is not None:
            self.tracer.emit(self.t, self.replica_id, victim.rid,
                             "oom_evict", ask=float(ask))
        if int(victim.prompt_len + ask) <= victim.prompt_len + victim.generated:
            self.dropped += 1      # unservable: exceeds the entire KV pool
            if self.tracer is not None:
                self.tracer.emit(self.t, self.replica_id, victim.rid,
                                 "dropped", need=int(victim.prompt_len + ask))
            return
        victim.reserve_len = float(ask)
        self._push_ready(victim)

    def _decode_tick_vec(self):
        """Vectorized decode over all active slots. Falls back to the
        reference loop on ticks with reservation growth (rare), where budget
        interactions are inherently sequential — keeping both paths exact."""
        n = self._n_active
        if n == 0:
            return
        sp = self.spec.speed
        pref = self._a_pref[:n] > 0
        emit = np.where(pref, 0,
                        np.minimum(sp, self._a_tlen[:n] - self._a_gen[:n]))
        if bool(np.any(self._a_plen[:n] + self._a_gen[:n] + emit
                       > self._a_res[:n])):
            # budget mode reaches here only on unconstrained ticks, where the
            # budgeted reference tick and the plain one agree — but route
            # through the budgeted one so the two paths share one code path
            # dispatch guard only: step() performs this same budget dispatch
            # for the reference path, so the knob is consulted on both
            if self._budget is None:  # reprolint: disable=dual-path-knob-parity
                self._decode_tick_ref()
            else:
                self._decode_tick_budget()
            return
        first = (self._a_gen[:n] == 0) & (emit > 0)
        if bool(first.any()):
            for i in np.nonzero(first)[0]:
                r = self._slots[int(i)]
                if r.t_first_token is None:
                    r.t_first_token = self.t
                    if self.tracer is not None:
                        self.tracer.emit(self.t, self.replica_id, r.rid,
                                         "first_token")
        self._progress = True
        self._a_pref[:n] -= pref
        self._a_gen[:n] += emit
        self._a_used[:n] += emit
        self._used_sum += int(emit.sum())
        finished = self._a_gen[:n] >= self._a_tlen[:n]
        if bool(finished.any()):
            for off, i in enumerate(np.nonzero(finished)[0]):
                self._finish_slot(int(i) - off)

    # budget-constrained ticks are always evented (ticks_to_event returns 1.0
    # via _budget_constrained), so the leap never spans a tick where the
    # chunk-allocation knobs below matter — deliberately reference-only
    def _decode_tick_budget(self):  # reprolint: disable=dual-path-knob-parity
        """One budgeted tick (``step_token_budget`` engines): prefill chunks
        and decode tokens draw from one shared token budget.

        1. *prefill*: each prefilling slot pulls up to ``prefill_chunk_tokens``
           of its remaining prompt from the budget, in ``Policy.chunk_order``
           (``fcfs`` = slot admission order, ``prod`` = predicted-short first,
           earliest deadline breaking ties). With ``prefill_chunk_tokens=0``
           (*atomic*) a prefill tick dedicates the whole budget to prefill
           and decode pauses — the non-chunked model.
        2. *decode*: the leftover budget feeds decoding slots in admission
           order, each emitting up to ``speed`` tokens; later slots emit
           less (or nothing) on ticks where prefill spent the budget. The
           reservation-growth/stall semantics mirror the reference loop.

        A slot whose last prefill chunk lands this tick emits its first
        token next tick, matching the tick-based prefill model. This is the
        *reference* semantics for budget mode; the vectorized path uses it
        verbatim on constrained ticks, so both paths stay bit-identical.
        """
        self._progress = False
        n = self._n_active
        if n == 0:
            return
        sp = self.spec.speed
        left = int(self._budget)
        pf = [i for i in range(n) if self._a_pftok[i] > 0]
        if pf:
            if self.policy.chunk_order == "prod":
                def chunk_key(j):
                    r = self._slots[j]
                    dl = float(r.deadline) if r.deadline is not None \
                        else float("inf")
                    return (float(self._a_pred[j]), dl, j)
                pf.sort(key=chunk_key)
            cap = left if self._atomic else self._chunk
            for j in pf:
                if left <= 0:
                    break
                take = min(cap, int(self._a_pftok[j]), left)
                if take <= 0:
                    continue
                self._a_pftok[j] -= take
                left -= take
                self.prefill_ticks += 1
                self._progress = True
                if self.tracer is not None:
                    self.tracer.emit(self.t, self.replica_id,
                                     self._slots[j].rid, "prefill_chunk",
                                     take=int(take),
                                     left=int(self._a_pftok[j]))
            if self._atomic:
                left = 0    # non-chunked: a prefill tick pauses decode
        was_pref = {self._slots[j].rid for j in pf}
        i = 0
        while i < self._n_active:
            r = self._slots[i]
            if r.rid in was_pref:
                i += 1      # still prefilling (or finished its prompt this
                continue    # tick): first decode token comes next tick
            emit = min(sp, int(self._a_tlen[i] - self._a_gen[i]))
            if emit <= 0:
                # degenerate zero-remaining request: finishes without
                # emitting (and without charging the budget)
                self._progress = True
                self._finish_slot(i)
                continue
            emit = min(emit, left)
            if emit <= 0:
                i += 1      # budget spent upstream — not a memory stall,
                continue    # this tick already made progress elsewhere
            res = int(self._a_res[i])
            head = res - int(self._a_plen[i] + self._a_gen[i])
            if emit > head:
                if self.kv.grow(r.rid, max(int(0.25 * res), 16, sp)):
                    self._a_res[i] = self.kv.reserved[r.rid]
                    r.overflows += 1
                    head = int(self._a_res[i]) \
                        - int(self._a_plen[i] + self._a_gen[i])
                if emit > head:
                    emit = head     # partial; 0 == stalled this tick
            if emit <= 0:
                i += 1
                continue
            if r.t_first_token is None:
                r.t_first_token = self.t
                if self.tracer is not None:
                    self.tracer.emit(self.t, self.replica_id, r.rid,
                                     "first_token")
            self._a_gen[i] += emit
            self._a_used[i] += emit
            self._used_sum += emit
            left -= emit
            self._progress = True
            if self._a_gen[i] >= self._a_tlen[i]:
                self._finish_slot(i)
            else:
                i += 1
        if self._n_active and not self._progress:
            self._evict_stalled()

    def _budget_constrained(self) -> bool:
        """Is the *next* tick one the shared token budget can shape? True
        when any slot is prefilling (chunks interleave with decode) or the
        decoding slots' full demand exceeds the budget. Unconstrained ticks
        are plain fixed-speed decode — leapable with the legacy arithmetic.
        """
        n = self._n_active
        if n == 0:
            return False
        if bool((self._a_pftok[:n] > 0).any()):
            return True
        return n * self.spec.speed > self._budget

    def _refine_active(self):
        """Posterior refinement of every decoding slot (one refine tick).

        For each active slot with a ProD-D histogram and decode progress
        t > 0, re-condition on survival (P[L = ℓ | L > t] via the
        :class:`~repro.core.online.PosteriorRefiner`) and refresh:

        * the median → ``predicted_len`` / ``_a_pred`` (SRTF victim choice,
          ``chunk_order="prod"``, predicted-backlog routing);
        * the work quantile → ``pred_q`` (laxity / quantile-steal keys);
        * the reservation quantile → ``reserve_len`` + a KV ``reprice``
          (pages released when the posterior moved the page-rounded grant
          down, delta pages drawn — feasibility-checked — when it moved up).

        Reservation re-cuts happen at the request's *effective* dispatch
        level: for conformally-calibrated requests the level is recovered
        once from (histogram, ``cal_q``) — the OnlineAdapter's ACI-adjusted
        ``q_eff`` — and ``cal_q`` is refreshed to the posterior quantile at
        that same level, so ACI coverage tracks the refreshed estimate
        (conformal-on-posterior). Slots still prefilling, at t = 0, or
        without a histogram (oracle annotation paths) are skipped; so are
        ``reserve="max"``/``"oracle"`` reservations (cap/realized — nothing
        to re-cut), though their ordering quantiles still refresh."""
        pol = self.policy
        rz = self.refiner
        sp = self.spec.speed
        for i in range(self._n_active):
            if self._a_pref[i] > 0 or self._a_pftok[i] > 0:
                continue            # prefilling: no decode progress yet
            t_dec = float(self._a_gen[i])
            if t_dec <= 0.0:
                continue            # posterior == prior at t = 0
            r = self._slots[i]
            p = r.pred_probs
            if p is None:
                continue            # no histogram attached (oracle paths)
            med, work = rz.quantiles(p, t_dec, (0.5, rz.work_quantile))
            r.predicted_len = float(med)
            r.pred_q = float(work)
            self._a_pred[i] = float(med)
            self.refine_events += 1
            action = "refresh"      # ordering quantiles only
            if pol.reserve == "quantile":
                if r.pred_level is None:
                    r.pred_level = rz.level_of(p, r.cal_q) \
                        if r.cal_q is not None else float(pol.quantile)
                tgt = rz.quantile(p, t_dec, r.pred_level)
            elif pol.reserve == "predicted":
                tgt = float(med) * pol.margin
            else:
                tgt = None          # max/oracle: reservation not prediction-cut
            if tgt is not None:
                res = float(min(max(tgt, 8.0), pol.max_seq_len))
                r.reserve_len = res
                if r.cal_q is not None:
                    r.cal_q = res   # conformal-on-posterior (see docstring)
                # page-boundary move only: floor at current content + one tick
                # of headroom so a shrink never forces an immediate
                # grow/overflow
                want = max(int(r.prompt_len) + int(np.ceil(res)),
                           int(self._a_used[i]) + sp)
                cur = self.kv.pages_of(r.rid)
                if self.kv.reprice(r.rid, want):
                    new = self.kv.pages_of(r.rid)
                    if new < cur:
                        self.refine_shrinks += 1
                        action = "shrink"
                    elif new > cur:
                        self.refine_grows += 1
                        action = "grow"
                    self._a_res[i] = self.kv.reserved[r.rid]
            if self.tracer is not None:
                self.tracer.emit(self.t, self.replica_id, r.rid, "refine",
                                 med=float(med), action=action)

    def step(self):
        """One engine tick: admit → (preempt) → decode one token per slot."""
        if self._refine_every and self.t >= self._next_refine:
            self._refine_active()
            self._next_refine = (np.floor(self.t / self._refine_every) + 1.0) \
                * self._refine_every
        if self._sample_every and self.t >= self._next_sample:
            # gauges read pre-admit state; sample ticks are evented (see
            # ticks_to_event), so both decode paths sample identical state
            self.tracer.sample_engine(self, self.t)
            self._next_sample = (np.floor(self.t / self._sample_every) + 1.0) \
                * self._sample_every
        if (self._n_active == 0 and not self._ready
                and (not self._future or self._future[0][0] > self.t)):
            self.t += 1.0   # fully idle tick: nothing to admit or decode
            return
        self._admit()
        self._maybe_preempt()
        self.t += 1.0
        if self._budget is not None:
            # budgeted engines: constrained ticks run the budgeted reference
            # tick (inherently sequential allocation); unconstrained ticks
            # are plain fixed-speed decode, so the vectorized fast path
            # applies unchanged and stays bit-identical
            if self.vectorized and not self._budget_constrained():
                self._decode_tick_vec()
            else:
                self._decode_tick_budget()
        elif self.vectorized:
            self._decode_tick_vec()
        else:
            self._decode_tick_ref()
        # reservation/usage integrals (waste metric), kept on the KV manager.
        # Physical usage = active slots' private content + each live shared
        # page's content once (shared_now); the logical integral is what a
        # sharing-blind pool would have reserved (kv_amplification's
        # numerator). Sharing off: shared_now == 0, logical == reserved.
        self.kv.total_reserved_steps += self.kv.reserved_now
        self.kv.total_asked_steps += self.kv.asked_now
        self.kv.total_used_steps += self._used_sum + self.kv.shared_now
        self.kv.total_logical_steps += self.kv.logical_now
        self._held_steps += self._held_tokens

    def advance_to(self, t: float):
        """Idle-skip the clock (no decode work in between)."""
        self.t = max(self.t, t)

    # -- event leap (vectorized fast path) -----------------------------------

    def ticks_to_event(self) -> float:
        """Ticks until the next tick that can admit, preempt, grow, complete,
        finish a prefill, expire a queued deadline, or see an arrival become
        due. Every tick strictly before that is provably eventless: prefilling
        slots burn one prefill tick, decoding slots emit ``speed`` tokens
        each, so the whole span can be advanced in closed form by
        :meth:`leap`."""
        k = np.inf
        sp = self.spec.speed
        if self._sample_every:
            # gauge-sample ticks are evented even when idle (an idle replica
            # still reports queue depth / occupancy rows), so a leap never
            # spans one and both decode paths sample at identical ticks
            k = min(k, max(1.0, self._next_sample - self.t))
        # lookahead mirror of step()'s refine prologue (shared by both decode
        # paths); the reference path needs no lookahead — it steps every tick
        if self._refine_every and self._n_active:  # reprolint: disable=dual-path-knob-parity
            # refine ticks are evented (like budget-constrained ticks):
            # leaps never span a posterior refresh, so both decode paths
            # refine at identical ticks and stay bit-exact
            k = min(k, max(1.0, self._next_refine - self.t))
        if self._future:
            # arrival due at the tick whose start time ≥ arrival
            k = min(k, max(1.0, np.ceil(self._future[0][0] - self.t) + 1.0))
        if self._ready:
            cand = self._ready[0][2]
            need = int(cand.prompt_len + cand.reserve_len)
            # mirror of _expire_ready_head's sharing-aware servability check
            if not self.kv.servable(cand.rid, need, *self._prefix_args(cand)):
                return 1.0   # unservable-head drop fires next tick
            # admission lookahead mirrors _admit's slot check (common to both
            # decode paths); admissions are evented, so leaps never span one
            if self._n_active < self.max_slots and (  # reprolint: disable=dual-path-knob-parity
                    self.kv.can_reserve(cand.rid, need,
                                        *self._prefix_args(cand))
                    # conservative: the held-pages stall breaker may free
                    # enough for the head — let the real step decide
                    or (self._n_active == 0 and self._held_ready > cand.held)):
                return 1.0   # admission fires next tick
            if cand.deadline is not None:
                # head expires at the first tick with t > deadline
                k = min(k, max(1.0, np.floor(cand.deadline - self.t) + 1.0))
            # preemption lookahead mirrors _maybe_preempt (common prologue of
            # both decode paths); preemptions are evented ticks
            if self.policy.preempt and self._n_active:  # reprolint: disable=dual-path-knob-parity
                n = self._n_active
                rem = np.maximum(self._a_pred[:n] - self._a_gen[:n], 1.0)
                if (rem.max() > self.policy.preempt_factor  # reprolint: disable=dual-path-knob-parity
                        * predicted_remaining(cand)):
                    return 1.0   # preemption fires next tick (monotone ↓)
        n = self._n_active
        if n and self._budget is not None and self._budget_constrained():
            # budget-shaped tick (prefill chunks in flight, or decode demand
            # over the budget): allocation is sequential and stateful, so
            # every such tick is evented; leaps only span unconstrained
            # pure-decode stretches where the legacy arithmetic is exact
            return 1.0
        if n:
            pref = self._a_pref[:n]
            prefilling = pref > 0
            if bool(prefilling.any()):
                # first decode tick of a prefilling slot is an event
                k = min(k, float(pref[prefilling].min()) + 1.0)
            if not bool(prefilling.all()):
                dec = ~prefilling
                rem = (self._a_tlen[:n] - self._a_gen[:n])[dec]
                k = min(k, float(np.ceil(rem / sp).min()))       # completion
                headroom = (self._a_res[:n] - self._a_plen[:n]
                            - self._a_gen[:n])[dec]
                k = min(k, float((headroom // sp).min() + 1))    # growth
        return max(k, 1.0)

    def leap(self, q: int):
        """Advance q provably-eventless ticks at once — bit-identical to q
        calls of :meth:`step` (each decoding slot emits ``speed`` tokens per
        tick, each prefilling slot burns one prefill tick; the usage integral
        is the arithmetic series the per-tick loop would sum)."""
        if q <= 0:
            return
        n = self._n_active
        if n:
            add = np.where(self._a_pref[:n] > 0, 0, self.spec.speed)
            self._a_pref[:n] -= np.minimum(self._a_pref[:n], q)
            first = (self._a_gen[:n] == 0) & (add > 0)
            if bool(first.any()):
                # a decoding slot entering the leap with no output emits its
                # first token on the span's first tick; with tracing on, the
                # event the per-tick loop would emit there is synthesized
                # from the canonical slot state at this leap boundary
                for i in np.nonzero(first)[0]:
                    r = self._slots[int(i)]
                    if r.t_first_token is None:
                        r.t_first_token = self.t + 1.0
                        if self.tracer is not None:
                            self.tracer.emit(self.t + 1.0, self.replica_id,
                                             r.rid, "first_token")
            gain = add * q
            self._a_gen[:n] += gain
            self._a_used[:n] += gain
            rate = int(add.sum())   # decode tokens emitted per tick
        else:
            rate = 0
        self.kv.total_used_steps += (q * (self._used_sum + self.kv.shared_now)
                                     + rate * q * (q + 1) // 2)
        self.kv.total_reserved_steps += q * self.kv.reserved_now
        self.kv.total_asked_steps += q * self.kv.asked_now
        self.kv.total_logical_steps += q * self.kv.logical_now
        self._held_steps += q * self._held_tokens
        self._used_sum += rate * q
        self.t += float(q)

    # -- closed-loop convenience --------------------------------------------

    def run(self, requests: List[Request], max_steps: int = 1_000_000) -> ServeStats:
        """Closed-loop single-replica replay: annotate, submit, step to idle.

        Parameters
        ----------
        requests : the workload; defensively copied (:meth:`Request.fresh_copy`)
            and annotated via the engine's ``predictor`` + ``policy``, so the
            caller's objects are never mutated and re-runs are reproducible.
        max_steps : hard tick cap (guards pathological non-termination).

        Returns a :class:`ServeStats` row; per-request outcomes stay on
        :attr:`done` / :attr:`timed_out_requests`.
        """
        self.reset()
        reqs = [r.fresh_copy() for r in requests]  # defensive copy
        annotate_predictions(reqs, self.predictor, self.policy)
        if self.tracer is not None:
            for r in reqs:
                self.tracer.emit(r.arrival, self.replica_id, r.rid, "arrival")
        self.submit(reqs)
        while not self.idle and self.t < max_steps:
            if self.vectorized:
                q = int(min(self.ticks_to_event() - 1,
                            max(max_steps - self.t - 1, 0)))
                self.leap(q)
            self.step()
            if self._n_active == 0 and not self._ready and self._future:
                self.advance_to(float(np.floor(self._future[0][0])))
        return self.stats()

    def stats(self) -> ServeStats:
        toks = sum(r.true_len for r in self._done)
        denom = max(self.t, 1.0) * max(self.kv.capacity_tokens, 1)
        return ServeStats(
            policy=f"{self.policy.order}+{self.policy.reserve}",
            makespan=self.t,
            throughput=toks / max(self.t, 1.0),
            kv_waste_ratio=self.kv.waste_ratio,
            overflow_events=self.kv.overflow_events,
            peak_reserved=self.kv.peak_reserved,
            completed=len(self._done),
            preemptions=self.preemptions,
            oom_evictions=self.oom_evictions,
            dropped=self.dropped,
            timed_out=self.timed_out,
            slo_violations=self.slo_violations,
            goodput=_goodput(self._done, self.t),
            page_size=self.kv.page_size,
            occupancy=self.kv.total_reserved_steps / denom,
            frag_ratio=self.kv.frag_ratio,
            held_peak=self._held_peak,
            held_steps=self._held_steps,
            held_releases=self.held_releases,
            recompute_ticks=self.recompute_ticks,
            kv_amplification=self.kv.kv_amplification,
            prefix_hits=self.kv.prefix_hits,
            cow_copies=self.kv.cow_copies,
            prefix_evictions=self.kv.prefix_evictions,
            shared_peak=self.kv.shared_peak,
            prefill_ticks=self.prefill_ticks,
            prefill_saved_ticks=self.prefill_saved_ticks,
            refine_events=self.refine_events,
            refine_shrinks=self.refine_shrinks,
            refine_grows=self.refine_grows,
            **_latency_stats(self._done),
            **_ttft_stats(self._done),
        )


# ---------------------------------------------------------------------------
# Track B: real generation with a tiny JAX LM
# ---------------------------------------------------------------------------


class RealEngine:
    """Batched sampling engine over a real (tiny) model: prefill once, decode
    until EOS, harvest last-token hidden states for the ProD predictor."""

    def __init__(self, model, params, rt=None, temperature: float = 0.8,
                 max_new: int = 256, eos_id: int = 2):
        import jax
        import jax.numpy as jnp
        from repro.models.model_zoo import Runtime

        self.model = model
        self.params = params
        self.rt = rt or Runtime.local()
        self.temp = temperature
        self.max_new = max_new
        self.eos = eos_id
        self._jit_prefill = jax.jit(
            lambda p, b: model.prefill(p, b, self.rt)
        )
        self._jit_decode = jax.jit(
            lambda p, b, c: model.decode_step(p, b, c, self.rt)
        )

    def generate(self, prompts: np.ndarray, prompt_lens: np.ndarray, key,
                 collect_hidden: bool = True, collect_per_step: bool = False):
        """prompts: (B, Sp) right-padded. Returns dict with lengths (B,),
        phi (B, d) last-prompt-token hidden, tokens (B, max_new), and —
        with ``collect_per_step`` — step_hidden (B, max_new, d) + step_valid
        (B, max_new), the per-decode-step states φ(z_t) for the online
        remaining-length predictor (paper §2.2's general t>0 case)."""
        import jax
        import jax.numpy as jnp
        from repro.models.model_zoo import last_token_hidden

        B, Sp = prompts.shape
        cfg = self.model.cfg
        valid = np.arange(Sp)[None, :] < prompt_lens[:, None]
        batch = {"tokens": jnp.asarray(prompts),
                 "attn_valid": jnp.asarray(valid)}
        logits, hidden, cache, _ = self._jit_prefill(self.params, batch)
        phi = last_token_hidden(hidden, jnp.asarray(prompt_lens)) if collect_hidden else None

        # move prefill cache into a decode cache with room for max_new tokens
        cache = self._grow_cache(cache, Sp + self.max_new, Sp)
        lengths = jnp.asarray(prompt_lens, jnp.int32)
        last_logit = logits[jnp.arange(B), lengths - 1]
        finished = jnp.zeros(B, bool)
        out_tokens = np.zeros((B, self.max_new), np.int32)
        gen_len = np.zeros(B, np.int64)
        step_hidden = (np.zeros((B, self.max_new, cfg.d_model), np.float32)
                       if collect_per_step else None)
        step_valid = (np.zeros((B, self.max_new), bool)
                      if collect_per_step else None)
        cur_logits = last_logit
        for step in range(self.max_new):
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, cur_logits / self.temp, axis=-1)
            nxt = jnp.where(finished, self.eos, nxt).astype(jnp.int32)
            out_tokens[:, step] = np.asarray(nxt)
            newly = (~finished) & (nxt == self.eos)
            finished = finished | (nxt == self.eos)
            gen_len = np.where(np.asarray(newly), step + 1, gen_len)
            if bool(finished.all()):
                break
            dbatch = {"tokens": nxt, "pos": lengths, "lengths": lengths + 1}
            cur_logits, hid_t, cache = self._jit_decode(self.params, dbatch, cache)
            if collect_per_step:
                step_hidden[:, step] = np.asarray(hid_t, np.float32)
                step_valid[:, step] = ~np.asarray(finished)
            lengths = lengths + jnp.where(finished, 0, 1)
        gen_len = np.where(gen_len == 0, self.max_new, gen_len)
        return {"lengths": gen_len, "phi": np.asarray(phi) if phi is not None else None,
                "tokens": out_tokens, "step_hidden": step_hidden,
                "step_valid": step_valid}

    def _grow_cache(self, cache, new_len: int, old_len: int):
        import jax.numpy as jnp
        import jax.tree_util as jtu

        def grow(x):
            # attention caches: (..., S, KV, hd) with S == old_len (ring caches
            # are allocated at their window and left alone)
            if x.ndim >= 4 and x.shape[-3] == old_len:
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, new_len - old_len)
                return jnp.pad(x, pad)
            return x

        return jtu.tree_map(grow, cache)

    def repeated_sampling(self, prompts: np.ndarray, prompt_lens: np.ndarray,
                          r: int, seed: int = 0):
        """The paper's data-collection loop: r independent generations per
        prompt. Returns (lengths (B, r), phi (B, d))."""
        import jax

        B = prompts.shape[0]
        lens = np.zeros((B, r), np.int64)
        phi = None
        for j in range(r):
            out = self.generate(prompts, prompt_lens, jax.random.PRNGKey(seed * 997 + j),
                                collect_hidden=(j == 0))
            lens[:, j] = out["lengths"]
            if j == 0:
                phi = out["phi"]
        return lens, phi
