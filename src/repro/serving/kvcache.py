"""Paged KV-cache reservation accounting (the paper's §4 serving motivation).

Serving frameworks that reserve for the *maximum possible* output waste memory
and cap the batch; reserving for the *predicted* output admits more concurrent
requests but risks overflow re-reservations. This manager tracks both costs so
the benchmark can quantify the trade-off that length prediction buys.

The pool is **page-granular** (vLLM-style): ``budget_tokens`` is split into
``budget_tokens // page_size`` pages and every reservation is a whole number
of pages. A request that asks for ``n`` tokens is *granted*
``ceil(n / page_size) * page_size`` tokens; the ask is remembered separately
so the page-rounding slack shows up as **internal fragmentation**
(:attr:`frag_ratio`). ``page_size=1`` reproduces the original scalar token
counter bit-exactly — every comparison reduces to the same integer
arithmetic — which is what lets the engine's vectorized-vs-reference golden
tests anchor the paged rewrite.

Page-granular accounting is what makes **partial-reservation handoff**
possible: a preempted request can :meth:`shrink` its reservation down to the
pages it has already filled and keep holding them while it waits to resume
(``Policy.preempt_mode="keep"``), instead of releasing everything and
re-reserving — and re-prefilling — from scratch.

**Prefix sharing** (``share_prefixes=True``): requests that declare a common
context — ``admit(rid, n, prefix_id, prefix_len)`` — share the physical pages
holding that prefix instead of each reserving its own copy. Shared pages
carry a *refcount*, not a single owner; the first request to materialize a
page of the prefix contributes it to the prefix store, later requests attach
(``prefix_hits``) and skip re-prefilling the covered tokens
(:meth:`prefill_skip`). A request whose context diverges *inside* a shared
page pays a **copy-on-write**: it privatizes the boundary page
(``cow_copies``) rather than writing to the shared one. When the last holder
detaches, the prefix's pages stay resident as reclaimable cache
(``cached_now``) and are evicted LRU only when an allocation actually needs
them (``prefix_evictions``) — a later request with the same ``prefix_id``
revives them for free.

Sharing splits the books in two: **physical** (``reserved_now`` counts every
page once, no matter how many requests reference it) and **logical**
(``logical_now`` = Σ per-request grants, what a sharing-blind allocator would
have reserved). Their step-integral ratio is :attr:`kv_amplification` — how
many tokens of KV capacity sharing manufactured per physical token. With
sharing off the two coincide and every code path is bit-identical to the
non-sharing manager.

Accounting is O(1) per operation (page *counts*, not page IDs). Pass
``track_pages=True`` to additionally materialize an explicit free-page stack
and per-request page tables — O(pages) per op, used by the allocator property
tests (no page leaked or double-assigned) and by the external-fragmentation
probe :meth:`fragmentation`. Prefix-owned pages live in their
:class:`_Prefix` entry's ``ids`` list, never in a request's page table.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class _Prefix:
    """One shared-prefix entry: ``pages`` physical pages holding the common
    context, referenced by ``refs`` live requests (0 = retained cache,
    reclaimable). ``stamp`` orders LRU eviction; ``ids`` are the page IDs
    when the pool tracks them."""
    pages: int = 0
    refs: int = 0
    stamp: int = 0
    ids: List[int] = field(default_factory=list)


@dataclass
class KVCacheManager:
    budget_tokens: int                       # total KV slots across the pool
    page_size: int = 1                       # tokens per page (1 = scalar mode)
    track_pages: bool = False                # materialize page IDs (tests)
    share_prefixes: bool = False             # ref-counted prefix page sharing
    reserved: Dict[int, int] = field(default_factory=dict)  # rid -> granted
    asked: Dict[int, int] = field(default_factory=dict)     # rid -> requested
    used: Dict[int, int] = field(default_factory=dict)
    reserved_now: int = 0                    # Σ live *physical* tokens
    asked_now: int = 0                       # Σ asked tokens, incremental
    used_now: int = 0                        # Σ used tokens, incremental
    logical_now: int = 0                     # Σ per-request grants (sharing-blind)
    shared_now: int = 0                      # live (refs>0) prefix tokens
    cached_now: int = 0                      # retained refs==0 prefix tokens
    peak_reserved: int = 0
    peak_logical: int = 0
    shared_peak: int = 0
    overflow_events: int = 0
    prefix_hits: int = 0                     # admits that reused prefix pages
    prefix_misses: int = 0                   # admits that registered a new one
    cow_copies: int = 0                      # boundary pages privatized
    prefix_evictions: int = 0                # cached prefixes reclaimed (LRU)
    total_reserved_steps: float = 0.0        # token-steps of physical reservation
    total_asked_steps: float = 0.0           # token-steps actually asked for
    total_used_steps: float = 0.0
    total_logical_steps: float = 0.0         # token-steps of logical grants
    page_table: Dict[int, List[int]] = field(default_factory=dict)
    prefixes: Dict[str, _Prefix] = field(default_factory=dict)
    _free_ids: List[int] = field(default_factory=list)
    _attached: Dict[int, str] = field(default_factory=dict)   # rid -> prefix
    _shared_tok: Dict[int, int] = field(default_factory=dict)  # rid -> tokens
    _skip: Dict[int, int] = field(default_factory=dict)  # rid -> prefill skip
    _clock: int = 0                          # LRU stamp source

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.pages_total = self.budget_tokens // self.page_size
        self.pages_free = self.pages_total
        if self.track_pages:
            # LIFO free stack: churn scrambles it, so page tables genuinely
            # fragment — what the fragmentation() probe measures
            self._free_ids = list(range(self.pages_total - 1, -1, -1))

    # -- page math -----------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (ceil division)."""
        return -(-int(n_tokens) // self.page_size)

    def pages_of(self, rid: int) -> int:
        """Pages currently granted to ``rid`` (0 if unknown). Includes the
        shared prefix pages its grant is backed by."""
        return self.reserved.get(rid, 0) // self.page_size

    @property
    def capacity_tokens(self) -> int:
        """Usable pool size: whole pages only (== budget when aligned)."""
        return self.pages_total * self.page_size

    @property
    def pages_reserved(self) -> int:
        """Physically allocated pages (live reservations, live prefixes, and
        retained prefix cache)."""
        return self.pages_total - self.pages_free

    @property
    def shared_pages(self) -> int:
        """Live shared-prefix pages (each counted once)."""
        return self.shared_now // self.page_size

    @property
    def occupancy(self) -> float:
        """Fraction of the pool's pages currently reserved."""
        if self.pages_total == 0:
            return 0.0
        return self.pages_reserved / self.pages_total

    # -- allocation ----------------------------------------------------------

    def _take_pages(self, rid: int, k: int):
        self.pages_free -= k
        if self.track_pages:
            tbl = self.page_table.setdefault(rid, [])
            for _ in range(k):
                tbl.append(self._free_ids.pop())

    def _give_pages(self, rid: int, k: int):
        self.pages_free += k
        if self.track_pages:
            tbl = self.page_table.get(rid, [])
            for _ in range(k):
                self._free_ids.append(tbl.pop())
            if not tbl:
                self.page_table.pop(rid, None)

    # -- prefix store --------------------------------------------------------

    def has_prefix(self, prefix_id: str) -> bool:
        """Is this prefix resident here (live or retained cache)? The
        prefix-affinity router's residency signal."""
        return prefix_id in self.prefixes

    def shared_tokens_of(self, rid: int) -> int:
        """Tokens of ``rid``'s grant backed by shared prefix pages."""
        return self._shared_tok.get(rid, 0)

    def prefill_skip(self, rid: int) -> int:
        """Prompt tokens ``rid`` can skip re-prefilling: covered by a prefix
        cache hit (plus a copy-on-write boundary page's copied content)."""
        return self._skip.get(rid, 0)

    def _reclaimable(self, exclude: Optional[str]) -> int:
        """Retained-cache pages an allocation could evict (LRU), excluding
        the prefix the allocation itself is about to attach to."""
        if not self.share_prefixes or self.cached_now == 0:
            return 0
        pages = self.cached_now // self.page_size
        if exclude is not None:
            e = self.prefixes.get(exclude)
            if e is not None and e.refs == 0:
                pages -= e.pages
        return pages

    def _avail_pages(self, exclude: Optional[str] = None) -> int:
        return self.pages_free + self._reclaimable(exclude)

    def _reclaim(self, need: int, exclude: Optional[str] = None):
        """Evict refs==0 prefix entries (oldest stamp first) until ``need``
        pages are free. No page is ever freed while shared (refs > 0)."""
        if self.pages_free >= need or not self.share_prefixes:
            return
        victims = sorted((e.stamp, k) for k, e in self.prefixes.items()
                         if e.refs == 0 and k != exclude)
        for _, key in victims:
            if self.pages_free >= need:
                break
            e = self.prefixes.pop(key)
            self.pages_free += e.pages
            self.cached_now -= e.pages * self.page_size
            if self.track_pages:
                self._free_ids.extend(reversed(e.ids))
            self.prefix_evictions += 1

    def _sharing(self, prefix_id: Optional[str], prefix_len: int) -> bool:
        return (self.share_prefixes and prefix_id is not None
                and prefix_len > 0)

    def _admit_need(self, n_tokens: int, prefix_id: Optional[str],
                    prefix_len: int):
        """Physical pages a fresh admit would newly allocate, and the prefix
        key it would attach to (the reclaim-exclusion). The single source of
        truth :meth:`can_admit`/:meth:`can_reserve`/:meth:`admit` all use —
        the feasibility check and the grant can't drift apart."""
        k_total = self.pages_for(n_tokens)
        if not self._sharing(prefix_id, prefix_len):
            return k_total, None
        target = min(int(prefix_len), int(n_tokens)) // self.page_size
        entry = self.prefixes.get(prefix_id)
        hit = min(entry.pages, target) if entry is not None else 0
        return k_total - hit, prefix_id

    def can_admit(self, n_tokens: int, prefix_id: Optional[str] = None,
                  prefix_len: int = 0) -> bool:
        need, excl = self._admit_need(n_tokens, prefix_id, prefix_len)
        return need <= self._avail_pages(excl)

    def servable(self, rid: int, n_tokens: int,
                 prefix_id: Optional[str] = None, prefix_len: int = 0) -> bool:
        """Sharing-aware "could this request *ever* start here": the pages it
        would still have to allocate, against the whole pool. Unlike the raw
        ``pages_for(n_tokens) <= pages_total`` test, this routes through the
        same :meth:`_admit_need` arithmetic admission uses, so a session
        follow-up whose full need exceeds a small replica's pool but whose
        resident shared prefix already covers part of it is *not* declared
        unservable — the resident prefix pages are capacity the request does
        not need to find again. A keep-mode holder's kept pages likewise
        count toward its own need (only the delta pages must still fit).
        Retained (refs==0) cache never caps servability: it is reclaimable
        the moment an allocation wants the pages."""
        if rid in self.reserved:            # holder: delta on the kept pages
            want = max(int(n_tokens), self.asked[rid])
            return self.pages_for(want) - self.pages_of(rid) \
                <= self.pages_total
        need, _ = self._admit_need(n_tokens, prefix_id, prefix_len)
        return need <= self.pages_total

    def admit(self, rid: int, n_tokens: int, prefix_id: Optional[str] = None,
              prefix_len: int = 0) -> bool:
        if not self._sharing(prefix_id, prefix_len):
            k = self.pages_for(n_tokens)
            if k > self._avail_pages():
                return False
            self._reclaim(k)
            self._take_pages(rid, k)
            self.reserved[rid] = k * self.page_size
            self.asked[rid] = int(n_tokens)
            self.used[rid] = 0
            self.reserved_now += k * self.page_size
            self.logical_now += k * self.page_size
            self.asked_now += int(n_tokens)
            self._bump_peaks()
            return True
        return self._admit_shared(rid, int(n_tokens), prefix_id,
                                  min(int(prefix_len), int(n_tokens)))

    def _admit_shared(self, rid: int, n_tokens: int, prefix_id: str,
                      prefix_len: int) -> bool:
        ps = self.page_size
        k_total = self.pages_for(n_tokens)
        target = prefix_len // ps           # full pages inside the prefix
        rem = prefix_len - target * ps      # boundary tokens past them
        entry = self.prefixes.get(prefix_id)
        have = entry.pages if entry is not None else 0
        hit = min(have, target)
        ext = max(0, target - have)         # prefix pages this admit registers
        # copy-on-write: the context diverges inside a page the prefix store
        # holds — privatize that boundary page instead of writing to it; the
        # copied content still skips re-prefill
        cow = entry is not None and have > target and rem > 0
        need_new = k_total - hit            # ext prefix pages + private pages
        if need_new > self._avail_pages(prefix_id):
            return False
        self._reclaim(need_new, prefix_id)
        self._clock += 1
        if entry is not None:
            if hit > 0 or cow:
                self.prefix_hits += 1
            entry.stamp = self._clock
        else:
            self.prefix_misses += 1
            if ext > 0:
                entry = self.prefixes[prefix_id] = _Prefix(stamp=self._clock)
        if cow:
            self.cow_copies += 1
        shared = 0
        if entry is not None and (hit > 0 or ext > 0):
            if entry.refs == 0 and entry.pages > 0:   # revive retained cache
                tok = entry.pages * ps
                self.cached_now -= tok
                self.reserved_now += tok
                self.shared_now += tok
            entry.refs += 1
            if ext > 0:                     # extend: new pages prefix-owned
                self.pages_free -= ext
                if self.track_pages:
                    for _ in range(ext):
                        entry.ids.append(self._free_ids.pop())
                entry.pages += ext
                self.reserved_now += ext * ps
                self.shared_now += ext * ps
            shared = (hit + ext) * ps
            self._attached[rid] = prefix_id
            self._shared_tok[rid] = shared
        self._take_pages(rid, k_total - hit - ext)      # private pages
        self.reserved[rid] = k_total * ps
        self.asked[rid] = n_tokens
        self.used[rid] = 0
        self.reserved_now += (k_total - hit - ext) * ps
        self.logical_now += k_total * ps
        self.asked_now += n_tokens
        self._skip[rid] = hit * ps + (rem if cow else 0)
        self._bump_peaks()
        return True

    def _bump_peaks(self):
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        self.peak_logical = max(self.peak_logical, self.logical_now)
        self.shared_peak = max(self.shared_peak, self.shared_now)

    def grow(self, rid: int, extra: int) -> bool:
        """Overflow: the request outgrew its reservation (mispredicted short).
        Grants whole pages — at least one: the caller only grows when out of
        granted space, and a zero-page "success" would let it emit past its
        reservation. The ask grows by exactly ``extra`` (what was actually
        requested); the grant may exceed it when the one-page minimum rounds
        up, and that slack is fragmentation, not demand."""
        want = self.asked[rid] + int(extra)
        delta = max(self.pages_for(want), self.pages_of(rid) + 1) \
            - self.pages_of(rid)
        if delta > self._avail_pages():
            return False
        self._reclaim(delta)
        self._take_pages(rid, delta)
        self.reserved[rid] += delta * self.page_size
        self.reserved_now += delta * self.page_size
        self.logical_now += delta * self.page_size
        self.asked_now += want - self.asked[rid]
        self.asked[rid] = want
        self.overflow_events += 1
        self._bump_peaks()
        return True

    # -- partial-reservation handoff (keep-pages preemption) -----------------

    def shrink(self, rid: int, keep_tokens: int) -> int:
        """Release every page beyond ``ceil(keep_tokens / page_size)`` —
        a preempted request keeping the pages it has already filled. Never
        grows, and never gives back shared prefix pages (they belong to the
        prefix store; only :meth:`release` detaches). Returns the new granted
        token count (page-rounded)."""
        keep = min(max(0, int(keep_tokens)), self.reserved[rid])
        keep = max(keep, self._shared_tok.get(rid, 0))
        k = self.pages_for(keep)
        self._give_pages(rid, self.pages_of(rid) - k)
        freed = self.reserved[rid] - k * self.page_size
        self.reserved_now -= freed
        self.logical_now -= freed
        self.asked_now += keep - self.asked[rid]
        self.reserved[rid] = k * self.page_size
        self.asked[rid] = keep
        if self.used.get(rid, 0) > keep:     # content beyond the kept pages
            self.used_now -= self.used[rid] - keep
            self.used[rid] = keep
        return self.reserved[rid]

    def reprice(self, rid: int, n_tokens: int) -> bool:
        """Move a live reservation to the page-rounded grant for ``n_tokens``
        — the posterior-refinement primitive. A smaller target shrinks
        (frees the pages beyond it and lowers the ask, so a later grow
        re-ratchets from the new level); a larger one grows through
        :meth:`reserve` (feasibility-checked delta pages, not counted as an
        overflow); an unchanged page count is a no-op (the ask keeps its
        dispatch value — same-page re-cuts are fragmentation noise, not
        demand). Returns whether the grant now covers ``n_tokens``; a
        refused grow leaves the reservation exactly as it was."""
        if rid not in self.reserved:
            return False
        want = max(0, int(n_tokens))
        k = self.pages_for(max(want, self._shared_tok.get(rid, 0)))
        cur = self.pages_of(rid)
        if k < cur:
            return self.shrink(rid, want) >= want
        if k > cur:
            if not self.can_reserve(rid, want):
                return False
            return self.reserve(rid, want)
        return True

    def can_reserve(self, rid: int, n_tokens: int,
                    prefix_id: Optional[str] = None,
                    prefix_len: int = 0) -> bool:
        """Admission feasibility — the *same* ``want`` :meth:`reserve` would
        grant: delta pages on a holder's ratcheted ask, fresh pages (minus
        any prefix hit) otherwise. ``can_reserve == reserve-would-succeed``
        by construction."""
        if rid in self.reserved:
            want = max(int(n_tokens), self.asked[rid])
            return self.pages_for(want) - self.pages_of(rid) \
                <= self._avail_pages()
        need, excl = self._admit_need(n_tokens, prefix_id, prefix_len)
        return need <= self._avail_pages(excl)

    def reserve(self, rid: int, n_tokens: int,
                prefix_id: Optional[str] = None, prefix_len: int = 0) -> bool:
        """Unified admission: a fresh request reserves its full need (joining
        its declared prefix, if any); a holder (preempted with kept pages)
        reserves only the *delta* pages on top of what it already holds. Not
        counted as an overflow."""
        if rid not in self.reserved:
            return self.admit(rid, n_tokens, prefix_id, prefix_len)
        want = max(int(n_tokens), self.asked[rid])
        delta = self.pages_for(want) - self.pages_of(rid)
        if delta > self._avail_pages():
            return False
        self._reclaim(delta)
        self._take_pages(rid, delta)
        self.reserved[rid] += delta * self.page_size
        self.reserved_now += delta * self.page_size
        self.logical_now += delta * self.page_size
        self.asked_now += want - self.asked[rid]
        self.asked[rid] = want
        self._bump_peaks()
        return True

    # -- usage / release -----------------------------------------------------

    def use(self, rid: int, n_tokens: int = 1):
        self.used[rid] = self.used.get(rid, 0) + n_tokens
        self.used_now += n_tokens

    def tick(self):
        """Accumulate per-step reservation/usage integrals (waste metric).
        O(1): the per-rid sums are kept incrementally in ``use``/``release``
        instead of re-summing the dicts in the hottest loop. ``used_now`` is
        the logical view; the engine integrates the physical one itself."""
        self.total_reserved_steps += self.reserved_now
        self.total_asked_steps += self.asked_now
        self.total_used_steps += self.used_now
        self.total_logical_steps += self.logical_now

    def release(self, rid: int):
        granted = self.reserved.pop(rid, 0)
        shared = self._shared_tok.pop(rid, 0)
        self._skip.pop(rid, None)
        self._give_pages(rid, (granted - shared) // self.page_size)
        self.reserved_now -= granted - shared
        self.logical_now -= granted
        prefix_id = self._attached.pop(rid, None)
        if prefix_id is not None:
            entry = self.prefixes[prefix_id]
            entry.refs -= 1
            if entry.refs == 0:
                # last holder gone: pages stay resident as reclaimable cache
                tok = entry.pages * self.page_size
                self.reserved_now -= tok
                self.shared_now -= tok
                self.cached_now += tok
        self.asked_now -= self.asked.pop(rid, 0)
        self.used_now -= self.used.pop(rid, 0)

    # -- metrics -------------------------------------------------------------

    @property
    def waste_ratio(self) -> float:
        if self.total_reserved_steps == 0:
            return 0.0
        return 1.0 - self.total_used_steps / self.total_reserved_steps

    @property
    def frag_ratio(self) -> float:
        """Internal fragmentation: the fraction of reserved token-steps that
        is page-rounding slack (granted − asked). 0 at ``page_size=1``."""
        if self.total_reserved_steps == 0:
            return 0.0
        return 1.0 - self.total_asked_steps / self.total_reserved_steps

    @property
    def kv_amplification(self) -> float:
        """Logical over physical reserved token-steps: how much KV capacity
        prefix sharing manufactured (1.0 with sharing off)."""
        if self.total_reserved_steps == 0:
            return 1.0
        return self.total_logical_steps / self.total_reserved_steps

    def fragmentation(self) -> float:
        """External fragmentation of the free list (``track_pages`` only):
        1 − largest contiguous free run / free pages. 0 when the free space
        is one run (or the pool is full)."""
        if not self.track_pages:
            raise ValueError("fragmentation() needs track_pages=True")
        if not self._free_ids:
            return 0.0
        ids = sorted(self._free_ids)
        best = run = 1
        for a, b in zip(ids, ids[1:]):
            run = run + 1 if b == a + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(ids)
