"""Paged KV-cache reservation accounting (the paper's §4 serving motivation).

Serving frameworks that reserve for the *maximum possible* output waste memory
and cap the batch; reserving for the *predicted* output admits more concurrent
requests but risks overflow re-reservations. This manager tracks both costs so
the benchmark can quantify the trade-off that length prediction buys.

The pool is **page-granular** (vLLM-style): ``budget_tokens`` is split into
``budget_tokens // page_size`` pages and every reservation is a whole number
of pages. A request that asks for ``n`` tokens is *granted*
``ceil(n / page_size) * page_size`` tokens; the ask is remembered separately
so the page-rounding slack shows up as **internal fragmentation**
(:attr:`frag_ratio`). ``page_size=1`` reproduces the original scalar token
counter bit-exactly — every comparison reduces to the same integer
arithmetic — which is what lets the engine's vectorized-vs-reference golden
tests anchor the paged rewrite.

Page-granular accounting is what makes **partial-reservation handoff**
possible: a preempted request can :meth:`shrink` its reservation down to the
pages it has already filled and keep holding them while it waits to resume
(``Policy.preempt_mode="keep"``), instead of releasing everything and
re-reserving — and re-prefilling — from scratch.

Accounting is O(1) per operation (page *counts*, not page IDs). Pass
``track_pages=True`` to additionally materialize an explicit free-page stack
and per-request page tables — O(pages) per op, used by the allocator property
tests (no page leaked or double-assigned) and by the external-fragmentation
probe :meth:`fragmentation`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class KVCacheManager:
    budget_tokens: int                       # total KV slots across the pool
    page_size: int = 1                       # tokens per page (1 = scalar mode)
    track_pages: bool = False                # materialize page IDs (tests)
    reserved: Dict[int, int] = field(default_factory=dict)  # rid -> granted
    asked: Dict[int, int] = field(default_factory=dict)     # rid -> requested
    used: Dict[int, int] = field(default_factory=dict)
    reserved_now: int = 0                    # Σ granted tokens, incremental
    asked_now: int = 0                       # Σ asked tokens, incremental
    used_now: int = 0                        # Σ used tokens, incremental
    peak_reserved: int = 0
    overflow_events: int = 0
    total_reserved_steps: float = 0.0        # token-steps of reservation
    total_asked_steps: float = 0.0           # token-steps actually asked for
    total_used_steps: float = 0.0
    page_table: Dict[int, List[int]] = field(default_factory=dict)
    _free_ids: List[int] = field(default_factory=list)

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.pages_total = self.budget_tokens // self.page_size
        self.pages_free = self.pages_total
        if self.track_pages:
            # LIFO free stack: churn scrambles it, so page tables genuinely
            # fragment — what the fragmentation() probe measures
            self._free_ids = list(range(self.pages_total - 1, -1, -1))

    # -- page math -----------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (ceil division)."""
        return -(-int(n_tokens) // self.page_size)

    def pages_of(self, rid: int) -> int:
        """Pages currently granted to ``rid`` (0 if unknown)."""
        return self.reserved.get(rid, 0) // self.page_size

    @property
    def capacity_tokens(self) -> int:
        """Usable pool size: whole pages only (== budget when aligned)."""
        return self.pages_total * self.page_size

    @property
    def pages_reserved(self) -> int:
        return self.pages_total - self.pages_free

    @property
    def occupancy(self) -> float:
        """Fraction of the pool's pages currently reserved."""
        if self.pages_total == 0:
            return 0.0
        return self.pages_reserved / self.pages_total

    # -- allocation ----------------------------------------------------------

    def _take_pages(self, rid: int, k: int):
        self.pages_free -= k
        if self.track_pages:
            tbl = self.page_table.setdefault(rid, [])
            for _ in range(k):
                tbl.append(self._free_ids.pop())

    def _give_pages(self, rid: int, k: int):
        self.pages_free += k
        if self.track_pages:
            tbl = self.page_table.get(rid, [])
            for _ in range(k):
                self._free_ids.append(tbl.pop())
            if not tbl:
                self.page_table.pop(rid, None)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= self.pages_free

    def admit(self, rid: int, n_tokens: int) -> bool:
        k = self.pages_for(n_tokens)
        if k > self.pages_free:
            return False
        self._take_pages(rid, k)
        self.reserved[rid] = k * self.page_size
        self.asked[rid] = int(n_tokens)
        self.used[rid] = 0
        self.reserved_now += k * self.page_size
        self.asked_now += int(n_tokens)
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        return True

    def grow(self, rid: int, extra: int) -> bool:
        """Overflow: the request outgrew its reservation (mispredicted short).
        Grants whole pages. The previous grant's page-rounding slack may
        absorb part of ``extra``, but a successful grow always adds at least
        one page — the caller only grows when out of granted space, and a
        zero-page "success" would let it emit past its reservation."""
        want = max(self.asked[rid] + int(extra), self.reserved[rid] + 1)
        delta = self.pages_for(want) - self.pages_of(rid)
        if delta > self.pages_free:
            return False
        self._take_pages(rid, delta)
        self.reserved[rid] += delta * self.page_size
        self.reserved_now += delta * self.page_size
        self.asked_now += want - self.asked[rid]
        self.asked[rid] = want
        self.overflow_events += 1
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        return True

    # -- partial-reservation handoff (keep-pages preemption) -----------------

    def shrink(self, rid: int, keep_tokens: int) -> int:
        """Release every page beyond ``ceil(keep_tokens / page_size)`` —
        a preempted request keeping the pages it has already filled. Never
        grows. Returns the new granted token count (page-rounded)."""
        keep = min(max(0, int(keep_tokens)), self.reserved[rid])
        k = self.pages_for(keep)
        self._give_pages(rid, self.pages_of(rid) - k)
        self.reserved_now -= self.reserved[rid] - k * self.page_size
        self.asked_now += keep - self.asked[rid]
        self.reserved[rid] = k * self.page_size
        self.asked[rid] = keep
        if self.used.get(rid, 0) > keep:     # content beyond the kept pages
            self.used_now -= self.used[rid] - keep
            self.used[rid] = keep
        return self.reserved[rid]

    def can_reserve(self, rid: int, n_tokens: int) -> bool:
        """Admission feasibility: delta pages for a partial holder, full
        pages otherwise."""
        have = self.pages_of(rid) if rid in self.reserved else 0
        return self.pages_for(n_tokens) - have <= self.pages_free

    def reserve(self, rid: int, n_tokens: int) -> bool:
        """Unified admission: a fresh request reserves its full need; a
        holder (preempted with kept pages) reserves only the *delta* pages on
        top of what it already holds. Not counted as an overflow."""
        if rid not in self.reserved:
            return self.admit(rid, n_tokens)
        want = max(int(n_tokens), self.asked[rid])
        delta = self.pages_for(want) - self.pages_of(rid)
        if delta > self.pages_free:
            return False
        self._take_pages(rid, delta)
        self.reserved[rid] += delta * self.page_size
        self.reserved_now += delta * self.page_size
        self.asked_now += want - self.asked[rid]
        self.asked[rid] = want
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        return True

    # -- usage / release -----------------------------------------------------

    def use(self, rid: int, n_tokens: int = 1):
        self.used[rid] = self.used.get(rid, 0) + n_tokens
        self.used_now += n_tokens

    def tick(self):
        """Accumulate per-step reservation/usage integrals (waste metric).
        O(1): the per-rid sums are kept incrementally in ``use``/``release``
        instead of re-summing the dicts in the hottest loop."""
        self.total_reserved_steps += self.reserved_now
        self.total_asked_steps += self.asked_now
        self.total_used_steps += self.used_now

    def release(self, rid: int):
        granted = self.reserved.pop(rid, 0)
        self._give_pages(rid, granted // self.page_size)
        self.reserved_now -= granted
        self.asked_now -= self.asked.pop(rid, 0)
        self.used_now -= self.used.pop(rid, 0)

    # -- metrics -------------------------------------------------------------

    @property
    def waste_ratio(self) -> float:
        if self.total_reserved_steps == 0:
            return 0.0
        return 1.0 - self.total_used_steps / self.total_reserved_steps

    @property
    def frag_ratio(self) -> float:
        """Internal fragmentation: the fraction of reserved token-steps that
        is page-rounding slack (granted − asked). 0 at ``page_size=1``."""
        if self.total_reserved_steps == 0:
            return 0.0
        return 1.0 - self.total_asked_steps / self.total_reserved_steps

    def fragmentation(self) -> float:
        """External fragmentation of the free list (``track_pages`` only):
        1 − largest contiguous free run / free pages. 0 when the free space
        is one run (or the pool is full)."""
        if not self.track_pages:
            raise ValueError("fragmentation() needs track_pages=True")
        if not self._free_ids:
            return 0.0
        ids = sorted(self._free_ids)
        best = run = 1
        for a, b in zip(ids, ids[1:]):
            run = run + 1 if b == a + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(ids)
