"""KV-cache reservation accounting (the paper's §4 serving motivation).

Serving frameworks that reserve for the *maximum possible* output waste memory
and cap the batch; reserving for the *predicted* output admits more concurrent
requests but risks overflow re-reservations. This manager tracks both costs so
the benchmark can quantify the trade-off that length prediction buys.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass
class KVCacheManager:
    budget_tokens: int                       # total KV slots across the pool
    reserved: Dict[int, int] = field(default_factory=dict)
    used: Dict[int, int] = field(default_factory=dict)
    reserved_now: int = 0                    # Σ reserved, kept incrementally
    peak_reserved: int = 0
    overflow_events: int = 0
    total_reserved_steps: float = 0.0        # token-steps of reservation
    total_used_steps: float = 0.0

    def can_admit(self, n_tokens: int) -> bool:
        return self.reserved_now + n_tokens <= self.budget_tokens

    def admit(self, rid: int, n_tokens: int) -> bool:
        if not self.can_admit(n_tokens):
            return False
        self.reserved[rid] = n_tokens
        self.used[rid] = 0
        self.reserved_now += n_tokens
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        return True

    def grow(self, rid: int, extra: int) -> bool:
        """Overflow: the request outgrew its reservation (mispredicted short)."""
        if self.reserved_now + extra > self.budget_tokens:
            return False
        self.reserved[rid] += extra
        self.reserved_now += extra
        self.overflow_events += 1
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        return True

    def use(self, rid: int, n_tokens: int = 1):
        self.used[rid] = self.used.get(rid, 0) + n_tokens

    def tick(self):
        """Accumulate per-step reservation/usage integrals (waste metric)."""
        self.total_reserved_steps += self.reserved_now
        self.total_used_steps += sum(self.used.values())

    def release(self, rid: int):
        self.reserved_now -= self.reserved.pop(rid, 0)
        self.used.pop(rid, None)

    @property
    def waste_ratio(self) -> float:
        if self.total_reserved_steps == 0:
            return 0.0
        return 1.0 - self.total_used_steps / self.total_reserved_steps
