"""Open-loop arrival traces for the cluster simulator.

Generates request streams whose decode lengths are drawn from the calibrated
heavy-tailed prompt-conditioned laws in :mod:`repro.data.scenarios` — any one
of the eight (served model × scenario) settings, or a traffic mix over all of
them — under three arrival processes:

* ``poisson``  — homogeneous Poisson (exponential interarrivals);
* ``bursty``   — 2-state Markov-modulated Poisson (calm/burst), normalized so
  the long-run mean rate equals ``rate``;
* ``diurnal``  — sinusoidally modulated rate via thinning,
  λ(t) = rate·(1 + amp·sin(2πt/period)); for ``amp > 1`` the sinusoid is
  clamped at 0 (dead-of-night silence) and rescaled so the long-run mean
  rate still equals ``rate``.

Traces can carry **shared context**: ``session_frac``/``agentic_frac`` turn
a fraction of base requests into multi-turn chat sessions / agentic tool
loops whose follow-up prompts extend the previous turn's full context, and
``system_prompt_len`` prepends a per-scenario common system prompt. Every
such request is tagged with ``prefix_id``/``prefix_len`` so a
``share_prefixes=True`` KV pool can back the common tokens with ref-counted
shared pages and the ``prefix_affine`` router can keep sessions where their
pages live. All knobs default off — the trace is then bit-identical to the
session-free generator.

Each request carries φ = its (noise-corrupted) length-law latents, so the
:class:`LatentOracle` can stand in for a trained ProD head at trace scale:
its median/quantile predictions are exact functionals of the corrupted
latents, and the corruption level follows the paper's feature-informativeness
calibration (``feature_sigma``) — chat traffic is genuinely harder to predict
than math. True lengths are drawn from the *clean* latents.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.lengths import law_quantile, sample_lengths, sample_prompt_latents
from repro.data.scenarios import ALL_SETTINGS, feature_sigma, get_spec
from repro.serving.request import Request


@dataclass(frozen=True)
class DriftSpec:
    """Non-stationary workload: how the length laws move mid-trace.

    The drift is deliberately *invisible in features*: the multiplier inflates
    the clean latents that true lengths are drawn from, while each request's
    φ keeps its pre-drift distribution. A predictor fit before the switch
    therefore silently under-covers afterwards — the regime the online
    adaptation subsystem (:mod:`repro.serving.adaptation`) exists for.

    Parameters
    ----------
    switch_step : trace time of the regime change.
    scale_mult : post-switch multiplier on every prompt's true-length median
        (1.0 = no scale drift).
    mix_weights : post-switch arrival weights over ``cfg.settings()`` — a
        scenario-mix shift (e.g. traffic turning mostly chat). ``None`` keeps
        the uniform mix.
    ramp_steps : 0 makes the scale change abrupt at ``switch_step``; > 0
        interpolates the log multiplier linearly over
        ``[switch_step, switch_step + ramp_steps]``.
    """

    switch_step: float
    scale_mult: float = 1.0
    mix_weights: Optional[Tuple[float, ...]] = None
    ramp_steps: float = 0.0

    def __post_init__(self):
        if self.switch_step < 0:
            raise ValueError("switch_step must be >= 0")
        if self.scale_mult <= 0:
            raise ValueError("scale_mult must be positive")
        if self.ramp_steps < 0:
            raise ValueError("ramp_steps must be >= 0")

    def log_scale_at(self, t: np.ndarray) -> np.ndarray:
        """Per-arrival log multiplier on the true-length median."""
        full = np.log(self.scale_mult)
        if self.ramp_steps <= 0:
            return np.where(np.asarray(t) >= self.switch_step, full, 0.0)
        frac = np.clip((np.asarray(t) - self.switch_step) / self.ramp_steps,
                       0.0, 1.0)
        return full * frac


@dataclass(frozen=True)
class TraceConfig:
    """Open-loop trace specification: arrivals × length laws × SLOs.

    Parameters
    ----------
    n_requests : trace length.
    pattern : arrival process — ``poisson`` | ``bursty`` (2-state MMPP) |
        ``diurnal`` (sinusoidally thinned); see module docstring.
    rate : long-run mean arrivals per engine step (all patterns normalize
        to it).
    model, scenario : which calibrated length law(s) draw decode lengths —
        a single setting or ``"mix"`` over all of them.
    seed : one seed drives arrivals, latents, lengths, and feature noise —
        traces are fully deterministic.
    prompt_min, prompt_max : uniform prompt-length range, both ends
        *inclusive* (KV admission cost).
    max_seq_len : serve cap; decode lengths are clipped to it.
    view : predictor probe view (``last``/``mean``/``proxy``/``entropy``) —
        sets the feature-noise level requests carry (see
        :func:`~repro.data.scenarios.feature_sigma`).
    slo_factor, slo_floor : per-class SLOs — deadline = arrival + slo_floor
        + slo_factor × the class law's median scale. Both 0 disables SLOs.
    drift : optional :class:`DriftSpec` making the workload non-stationary
        (scenario-mix shift and/or true-length scale inflation at a switch
        step). ``None`` keeps the stationary trace bit-identical to before.
    burst_* : bursty-pattern shape; diurnal_* : diurnal-pattern shape
        (``diurnal_amp`` must be >= 0; above 1 the modulated rate is clamped
        at 0 and renormalized to preserve the mean — see module docstring).
    session_frac : fraction of base requests that seed a multi-turn chat
        session; follow-up turns (1 + Geometric(mean ``session_turns_mean``)
        of them) re-submit the full previous context (prompt + answer) plus
        fresh user tokens, arriving ``exp(session_gap_mean)`` steps after
        the previous answer could have finished. Each turn carries
        ``prefix_id="chat/<seed rid>"`` with ``prefix_len`` = the inherited
        context. Session turns are *appended* to the trace: it then holds
        more than ``n_requests`` requests.
    agentic_frac : like ``session_frac`` but for agentic tool loops: short
        think-time gaps (``agentic_gap_mean``), small tool-output glue
        between turns, ``agentic_turns_mean`` extra calls on average
        (``prefix_id="agent/<seed rid>"``). A base request seeds at most one
        of the two (``session_frac + agentic_frac <= 1``).
    session_turns_mean, session_gap_mean, agentic_turns_mean,
    agentic_gap_mean : shape knobs for the above.
    system_prompt_len : tokens of a per-scenario common system prompt
        prepended to every base request's prompt
        (``prefix_id="sys/<setting>"``) — the classic always-shared prefix.
    """

    n_requests: int = 50_000
    pattern: str = "poisson"        # poisson | bursty | diurnal
    rate: float = 1.0               # mean arrivals per engine step
    model: str = "mix"              # qwen | llama | mix
    scenario: str = "mix"           # math | coding | longseq | chat | mix
    seed: int = 0
    prompt_min: int = 16
    prompt_max: int = 256
    max_seq_len: int = 4096         # decode lengths clipped to the serve cap
    view: str = "last"              # predictor probe view (feature noise)
    # per-class SLOs: deadline = arrival + slo_floor + slo_factor × the
    # setting's typical length (its law's median scale) — chat gets a bigger
    # absolute budget than math, the per-token budget is shared. 0 = no SLOs.
    slo_factor: float = 0.0
    slo_floor: float = 0.0
    # non-stationarity (None = stationary trace, unchanged behavior)
    drift: Optional[DriftSpec] = None
    # bursty (2-state MMPP)
    burst_rate_mult: float = 6.0
    burst_len_mean: float = 200.0   # mean steps per burst episode
    calm_len_mean: float = 1800.0
    # diurnal
    diurnal_period: float = 20_000.0
    diurnal_amp: float = 0.8
    # shared-context workloads (all 0 = off: trace bit-identical to before)
    session_frac: float = 0.0
    session_turns_mean: float = 3.0
    session_gap_mean: float = 200.0
    agentic_frac: float = 0.0
    agentic_turns_mean: float = 6.0
    agentic_gap_mean: float = 8.0
    system_prompt_len: int = 0

    def __post_init__(self):
        if self.diurnal_amp < 0:
            raise ValueError(
                f"diurnal_amp must be >= 0, got {self.diurnal_amp} (negative "
                "amplitudes are a phase shift in disguise; use amp >= 0)")
        if not 0.0 <= self.session_frac <= 1.0:
            raise ValueError("session_frac must be in [0, 1]")
        if not 0.0 <= self.agentic_frac <= 1.0:
            raise ValueError("agentic_frac must be in [0, 1]")
        if self.session_frac + self.agentic_frac > 1.0:
            raise ValueError("session_frac + agentic_frac must be <= 1")
        if self.system_prompt_len < 0:
            raise ValueError("system_prompt_len must be >= 0")
        if min(self.session_turns_mean, self.session_gap_mean,
               self.agentic_turns_mean, self.agentic_gap_mean) < 0:
            raise ValueError("session/agentic turn and gap means must be >= 0")
        if not 0 <= self.prompt_min <= self.prompt_max:
            raise ValueError("need 0 <= prompt_min <= prompt_max")

    @property
    def has_sessions(self) -> bool:
        """Does this trace carry any shared-context traffic?"""
        return (self.session_frac > 0 or self.agentic_frac > 0
                or self.system_prompt_len > 0)

    def settings(self) -> Tuple[Tuple[str, str], ...]:
        if self.model == "mix" and self.scenario == "mix":
            return ALL_SETTINGS
        models = ("qwen", "llama") if self.model == "mix" else (self.model,)
        scens = (("math", "coding", "longseq", "chat")
                 if self.scenario == "mix" else (self.scenario,))
        return tuple((m, s) for m in models for s in scens)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


def _poisson_arrivals(rng: np.random.Generator, n: int, rate: float) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _bursty_arrivals(cfg: TraceConfig, rng: np.random.Generator,
                     n: int) -> np.ndarray:
    """2-state MMPP: alternate exponential-length calm/burst episodes; draw
    the arrivals inside an episode as uniform order statistics of a Poisson
    count. Base rate is scaled so the long-run mean equals cfg.rate."""
    p_burst = cfg.burst_len_mean / (cfg.burst_len_mean + cfg.calm_len_mean)
    mean_mult = (1.0 - p_burst) + p_burst * cfg.burst_rate_mult
    base = cfg.rate / mean_mult
    out: List[np.ndarray] = []
    t, total, burst = 0.0, 0, False
    while total < n:
        mean_len = cfg.burst_len_mean if burst else cfg.calm_len_mean
        dur = float(rng.exponential(mean_len))
        lam = base * (cfg.burst_rate_mult if burst else 1.0)
        k = int(rng.poisson(lam * dur))
        if k:
            out.append(t + np.sort(rng.random(k)) * dur)
            total += k
        t += dur
        burst = not burst
    return np.concatenate(out)[:n]


def _diurnal_arrivals(cfg: TraceConfig, rng: np.random.Generator,
                      n: int) -> np.ndarray:
    """Inhomogeneous Poisson via thinning against the modulation's peak.

    ``amp <= 1``: λ(t) = rate·(1 + amp·sin(2πt/period)), mean-rate ``rate``
    by symmetry. ``amp > 1`` would push λ(t) negative through the troughs —
    the raw sinusoid is not a rate — so λ is clamped at 0 there and divided
    by the clipped sinusoid's mean, E[max(0, 1 + amp·sin θ)] =
    (π + 2·arcsin(1/amp) + 2·amp·cos(arcsin(1/amp))) / 2π, keeping the
    long-run mean rate equal to ``rate`` (the normalization every arrival
    pattern promises). Without the renormalization the clamp silently
    *inflates* the mean rate — the pre-fix bug."""
    amp = cfg.diurnal_amp
    if amp > 1.0:
        crit = np.arcsin(1.0 / amp)
        mean_pos = (np.pi + 2.0 * crit + 2.0 * amp * np.cos(crit)) \
            / (2.0 * np.pi)
    else:
        mean_pos = 1.0          # exact: keeps amp <= 1 traces bit-identical
    lam_max = cfg.rate * (1.0 + amp) / mean_pos
    kept: List[np.ndarray] = []
    t, total = 0.0, 0
    while total < n:
        chunk = max(1024, 2 * (n - total))
        cand = t + np.cumsum(rng.exponential(1.0 / lam_max, size=chunk))
        lam_t = cfg.rate / mean_pos * np.maximum(
            0.0, 1.0 + amp * np.sin(2.0 * np.pi * cand / cfg.diurnal_period))
        keep = cand[rng.random(chunk) < lam_t / lam_max]
        kept.append(keep)
        total += len(keep)
        t = float(cand[-1])
    return np.concatenate(kept)[:n]


def arrival_times(cfg: TraceConfig, rng: np.random.Generator) -> np.ndarray:
    n = cfg.n_requests
    if n <= 0:
        return np.zeros(0, np.float64)
    if cfg.pattern == "poisson":
        return _poisson_arrivals(rng, n, cfg.rate)
    if cfg.pattern == "bursty":
        return _bursty_arrivals(cfg, rng, n)
    if cfg.pattern == "diurnal":
        return _diurnal_arrivals(cfg, rng, n)
    raise ValueError(cfg.pattern)


# ---------------------------------------------------------------------------
# trace construction
# ---------------------------------------------------------------------------


def corrupt_latents(rng: np.random.Generator, lat: np.ndarray, spec,
                    view: str) -> np.ndarray:
    """Noise-corrupt clean length-law latents into predictor features.

    Adds ``feature_sigma(spec, view)``-scaled Gaussian noise to the log-median
    coordinate — the paper's feature-informativeness calibration (chat ≫
    math; last > mean > proxy > entropy). This one helper defines the feature
    distribution BOTH the trace generator (request φ) and
    :func:`~repro.serving.predictor.fit_trace_head` (training features) draw
    from, so the trained head is never evaluated off-distribution."""
    noisy = lat.copy()
    noisy[:, 0] += feature_sigma(spec, view) * rng.standard_normal(len(lat))
    return noisy


def make_trace(cfg: TraceConfig) -> List[Request]:
    """Build an open-loop request trace: Poisson/bursty/diurnal arrivals with
    heavy-tailed prompt-conditioned lengths from the calibrated scenario laws.

    Deterministic for a fixed config (single seeded Generator). Requests come
    back sorted by arrival with φ = noise-corrupted latents attached.

    With ``cfg.drift`` set the trace is non-stationary: arrivals after the
    switch step re-draw their scenario from ``drift.mix_weights`` and their
    *true* lengths from scale-inflated latents, while φ stays on the pre-drift
    feature distribution (see :class:`DriftSpec` — the drift is invisible to
    any predictor that only sees features)."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_requests
    arrivals = arrival_times(cfg, rng)
    settings = cfg.settings()
    pick = rng.integers(0, len(settings), size=n)
    drift = cfg.drift
    log_shift = None
    if drift is not None:
        if drift.mix_weights is not None:
            w = np.asarray(drift.mix_weights, np.float64)
            if w.shape != (len(settings),) or np.any(w < 0) or w.sum() <= 0:
                raise ValueError(
                    f"mix_weights must be {len(settings)} non-negative "
                    f"weights (one per cfg.settings() entry), got {w}")
            post_pick = rng.choice(len(settings), size=n, p=w / w.sum())
            pick = np.where(arrivals >= drift.switch_step, post_pick, pick)
        log_shift = drift.log_scale_at(arrivals)

    true_len = np.zeros(n, np.int64)
    phi = np.zeros((n, 4), np.float64)
    slo_budget = np.zeros(n, np.float64)
    for si, (model, scen) in enumerate(settings):
        idx = np.nonzero(pick == si)[0]
        if len(idx) == 0:
            continue
        spec = get_spec(model, scen)
        lat = sample_prompt_latents(rng, spec.law, len(idx))
        lat_true = lat
        if log_shift is not None:
            lat_true = lat.copy()
            lat_true[:, 0] += log_shift[idx]
        true_len[idx] = sample_lengths(rng, lat_true, 1, spec.law)[:, 0]
        phi[idx] = corrupt_latents(rng, lat, spec, cfg.view)
        slo_budget[idx] = cfg.slo_floor + cfg.slo_factor * spec.law.median_scale
    true_len = np.minimum(true_len, cfg.max_seq_len)
    # inclusive on both ends, as the TraceConfig docstring promises (the
    # pre-fix exclusive upper bound made prompt_max unreachable)
    plen = rng.integers(cfg.prompt_min, cfg.prompt_max, size=n, endpoint=True)
    with_slo = cfg.slo_factor > 0.0 or cfg.slo_floor > 0.0

    reqs = [
        Request(
            rid=i, arrival=float(arrivals[i]), prompt_len=int(plen[i]),
            true_len=int(true_len[i]), phi=phi[i],
            setting="/".join(settings[pick[i]]),
            deadline=float(arrivals[i] + slo_budget[i]) if with_slo else None,
        )
        for i in range(n)
    ]
    if cfg.has_sessions:
        _attach_sessions(cfg, rng, reqs, pick, settings, slo_budget, with_slo)
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def _attach_sessions(cfg: TraceConfig, rng: np.random.Generator,
                     reqs: List[Request], pick: np.ndarray, settings,
                     slo_budget: np.ndarray, with_slo: bool):
    """Turn base requests into shared-context traffic, in place.

    System prompts: every base request gets ``system_prompt_len`` extra
    prompt tokens tagged ``prefix_id="sys/<setting>"`` — one common prefix
    per scenario, shared across unrelated requests.

    Sessions/agentic loops: a ``session_frac``/``agentic_frac`` split of the
    base requests each seeds a turn chain. Turn k+1 resubmits turn k's whole
    context (prompt + realized answer) plus fresh user/tool tokens, arrives
    after the previous answer's decode time plus a think-time gap, and
    declares the inherited context via ``prefix_id``/``prefix_len`` so a
    sharing KV pool recognizes it. Follow-up turns draw fresh lengths from
    the seed's scenario law (stationary — drift applies to base arrivals
    only) and are *appended*: rids continue past ``n_requests``. Chains stop
    before the context would crowd out decode room under ``max_seq_len``.

    All extra randomness is drawn after the base trace is fully built, so
    switching sessions on never perturbs the base requests' draws."""
    n = len(reqs)
    rid = n
    if cfg.system_prompt_len > 0:
        for r in reqs:
            r.prompt_len += cfg.system_prompt_len
            r.prefix_id = f"sys/{r.setting}"
            r.prefix_len = cfg.system_prompt_len
    u = rng.random(n)
    extra: List[Request] = []
    ctx_cap = cfg.max_seq_len - max(64, cfg.prompt_max)
    for i in range(n):
        chat = u[i] < cfg.session_frac
        agentic = (not chat
                   and u[i] < cfg.session_frac + cfg.agentic_frac)
        if not (chat or agentic):
            continue
        seed = reqs[i]
        spec = get_spec(*settings[pick[i]])
        turns_mean = cfg.session_turns_mean if chat else cfg.agentic_turns_mean
        gap_mean = cfg.session_gap_mean if chat else cfg.agentic_gap_mean
        turns = int(rng.geometric(min(1.0, 1.0 / max(turns_mean, 1.0))))
        sid = f"{'chat' if chat else 'agent'}/{seed.rid}"
        ctx, prev_ans, t_prev = seed.prompt_len, seed.true_len, seed.arrival
        for _ in range(turns):
            fresh = int(rng.integers(cfg.prompt_min, cfg.prompt_max,
                                     endpoint=True)) if chat \
                else int(rng.integers(8, 32, endpoint=True))
            new_prompt = ctx + prev_ans + fresh
            if new_prompt > ctx_cap:
                break       # context budget exhausted: session ends
            lat = sample_prompt_latents(rng, spec.law, 1)
            t_len = min(int(sample_lengths(rng, lat, 1, spec.law)[0, 0]),
                        cfg.max_seq_len)
            t_arr = t_prev + float(prev_ans) + float(rng.exponential(gap_mean))
            extra.append(Request(
                rid=rid, arrival=t_arr, prompt_len=new_prompt,
                true_len=t_len,
                phi=corrupt_latents(rng, lat, spec, cfg.view)[0],
                setting=seed.setting,
                deadline=(t_arr + float(slo_budget[i])) if with_slo else None,
                prefix_id=sid, prefix_len=ctx + prev_ans,
            ))
            rid += 1
            ctx, prev_ans, t_prev = new_prompt, t_len, t_arr
    reqs.extend(extra)


class LatentOracle:
    """Trace-scale ProD-predictor proxy: predicts from each request's
    (noise-corrupted) length-law latents instead of a trained head.

    One of the three interchangeable predictors behind the cluster's
    ``predictor=`` seam — the analytic proxy, bracketed by the trained
    :class:`~repro.serving.predictor.PredictorService` (the paper's actual
    head) and the zero-error
    :class:`~repro.serving.predictor.PerfectOracle`.

    ``predict`` returns the body median exp(log m̃) — the ProD-M point
    estimate — and ``quantile`` inverts the full body+tail mixture CDF at the
    corrupted latents — the ProD-D distributional estimate. Because log m̃
    carries ``feature_sigma``-scaled noise, prediction quality degrades
    exactly where the paper says features are least informative."""

    def predict(self, phi: np.ndarray) -> np.ndarray:
        return np.exp(np.asarray(phi, np.float64)[:, 0])

    def quantile(self, phi: np.ndarray, q: float) -> np.ndarray:
        return law_quantile(np.asarray(phi, np.float64), q)


def mean_true_length(reqs: Sequence[Request]) -> float:
    return float(np.mean([r.true_len for r in reqs]))


def stable_rate(n_replicas: int, max_slots: int, mean_len: float,
                load: float = 0.7) -> float:
    """Arrival rate giving the cluster utilization ``load``: each slot emits
    one token per step, so capacity is n_replicas·max_slots/mean_len req/step."""
    return load * n_replicas * max_slots / max(mean_len, 1.0)


def stable_rate_specs(specs, mean_len: float, load: float = 0.7) -> float:
    """Heterogeneity-aware :func:`stable_rate`: cluster decode capacity is
    Σ slots·speed tokens/step over the :class:`ReplicaSpec` fleet (prefill
    cost is ignored — treat ``load`` as a decode-utilization target)."""
    service = float(sum(s.max_slots * s.speed for s in specs))
    return load * service / max(mean_len, 1.0)
