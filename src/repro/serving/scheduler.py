"""Schedulers: FCFS, SJF on predicted length, deadline-aware orderings, and
the uncertainty-aware quantile policy that only a distributional predictor
(ProD-D) enables.

Orderings:
* ``fcfs``       — arrival order
* ``sjf_pred``   — shortest predicted remaining length first
* ``sjf_oracle`` — shortest realized length first (upper bound)
* ``srtf_pred``  — sjf_pred + preemption of the longest-remaining active slot
* ``edf``        — earliest deadline first (requests without a deadline run
                   FCFS after all deadline-carrying ones)
* ``laxity``     — least laxity first, laxity = deadline − now − predicted
                   q0.9 remaining work. Since ``now`` is common to every
                   queued entry at any comparison instant, ordering by the
                   static key ``deadline − q0.9-remaining`` IS the least-
                   laxity order — no time-dependent re-keying needed.

Reservation policies:
* ``max``       — reserve max_seq_len (vLLM-naive; zero overflow, max waste)
* ``predicted`` — reserve predicted median × margin
* ``quantile``  — reserve the q-th quantile of the ProD-D predictive
                  distribution (per-request risk control; the CoRE-style
                  learning-for-scheduling coupling)
* ``oracle``    — reserve the realized length (upper bound)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from repro.serving.request import Request

ORDERINGS = ("fcfs", "sjf_pred", "sjf_oracle", "srtf_pred", "edf", "laxity")
RESERVES = ("max", "predicted", "quantile", "oracle")
PREEMPT_MODES = ("recompute", "keep")
# chunked-prefill budget allocation (ReplicaSpec.step_token_budget engines):
# which prefilling slot gets the next chunk of the per-step token budget
CHUNK_ORDERS = ("fcfs", "prod")


@dataclass(frozen=True)
class Policy:
    """Scheduling policy: queue ordering × KV reservation sizing.

    Parameters
    ----------
    order : one of :data:`ORDERINGS` (see module docstring).
    reserve : one of :data:`RESERVES` — how much KV to reserve per request.
    margin : multiplier on the predicted median for ``reserve="predicted"``.
    quantile : CDF level for ``reserve="quantile"``.
    max_seq_len : serve-time length cap; reservations clamp to it.
    preempt : SRTF only — evict the longest-remaining active slot when a much
        shorter request waits.
    preempt_factor : preempt only if the victim's predicted remaining exceeds
        this multiple of the newcomer's.
    preempt_mode : what happens to the victim's KV reservation, one of
        :data:`PREEMPT_MODES`. ``"recompute"`` releases it all and resume
        re-reserves — and re-prefills — from scratch; ``"keep"`` retains the
        pages the victim already filled (paged KV), so resume reserves only
        the delta pages and skips the prefill recompute.
    chunk_order : chunked-prefill only (engines with a
        ``ReplicaSpec.step_token_budget``), one of :data:`CHUNK_ORDERS` —
        which prefilling slot the per-step token budget feeds first.
        ``"fcfs"`` hands chunks out in slot admission order; ``"prod"`` is
        the ProD-aware allocation: predicted-short requests first (earliest
        deadline breaking ties), so short answers reach their first token
        before long ones monopolize the budget.
    refine_every : posterior length refinement period in engine ticks.
        Every ``refine_every`` ticks the engine re-conditions each active
        slot's ProD-D histogram on its decode progress
        (:class:`~repro.core.online.PosteriorRefiner`), refreshing the
        median / work-quantile / reservation quantiles that SRTF, laxity,
        stealing, chunk ordering, and KV sizing read. ``0`` (default)
        disables refinement entirely — bit-identical legacy behavior.
    """

    order: str = "fcfs"            # see ORDERINGS
    reserve: str = "max"           # see RESERVES
    margin: float = 1.2            # multiplier for `predicted`
    quantile: float = 0.9
    max_seq_len: int = 4096
    preempt: bool = False          # srtf: evict the longest-remaining active
    preempt_factor: float = 2.0    # only if its remaining > factor × newcomer's
    preempt_mode: str = "recompute"   # see PREEMPT_MODES
    chunk_order: str = "fcfs"         # see CHUNK_ORDERS
    refine_every: int = 0             # 0 = no mid-flight refinement

    def __post_init__(self):
        if self.order not in ORDERINGS:
            raise ValueError(f"order {self.order!r} not in {ORDERINGS}")
        if self.reserve not in RESERVES:
            raise ValueError(f"reserve {self.reserve!r} not in {RESERVES}")
        if self.preempt_mode not in PREEMPT_MODES:
            raise ValueError(
                f"preempt_mode {self.preempt_mode!r} not in {PREEMPT_MODES}")
        if self.chunk_order not in CHUNK_ORDERS:
            raise ValueError(
                f"chunk_order {self.chunk_order!r} not in {CHUNK_ORDERS}")
        if int(self.refine_every) != self.refine_every or self.refine_every < 0:
            raise ValueError("refine_every must be a non-negative integer "
                             "number of ticks (0 = off)")


def predicted_remaining(r: Request) -> float:
    """Estimated remaining tokens (ProD-O style: static estimate − progress)."""
    base = r.predicted_len if r.predicted_len is not None else float(r.true_len)
    return max(base - r.generated, 1.0)


def quantile_remaining(r: Request, max_cap: Optional[float] = None,
                       refiner=None) -> float:
    """Predicted q0.9 remaining work — the pessimistic remaining-tokens signal
    least-laxity ordering and quantile work stealing budget against.

    Fallback chain:

    1. ``pred_q`` — the PredictorService-attached true q0.9;
    2. ``reserve_len`` — but only when it carries per-request information
       (a quantile/predicted/oracle reservation). When ``max_cap`` (the
       policy's ``max_seq_len``) is given and the reservation sits at that
       cap — ``reserve="max"`` reserves the cap for *every* request — the
       reservation is a constant pseudo-quantile that would poison laxity
       ordering and quantile stealing, so it is skipped;
    3. the point prediction (``predicted_len``, else the realized length).

    ``refiner`` (a :class:`~repro.core.online.PosteriorRefiner`, passed by
    engines running with ``Policy.refine_every > 0``) repairs the
    over-runner collapse: a request that has outlived its dispatch-time
    quantile used to hit the ``max(base - generated, 1.0)`` floor, so every
    over-runner keyed identically (1.0) and SRTF/laxity ordering, quantile
    stealing, and victim choice among them degenerated to tie-break order.
    Conditioning the histogram on survival to ``generated`` keeps the
    remaining-work estimate well-defined (the posterior quantile is always
    above ``generated``), so over-runners stay mutually ordered by their
    tails."""
    if r.pred_q is not None:
        base = float(r.pred_q)
    elif r.reserve_len is not None and not (
            max_cap is not None and float(r.reserve_len) >= float(max_cap)):
        base = float(r.reserve_len)
    else:
        base = predicted_remaining(r) + r.generated
    if (refiner is not None and r.pred_probs is not None
            and base - r.generated < 1.0):
        base = refiner.quantile(r.pred_probs, float(r.generated),
                                refiner.work_quantile)
    return max(base - r.generated, 1.0)


def annotate_predictions(requests: List[Request], predictor, policy: Policy):
    """Attach predicted median + reservation length from the ProD head.

    ``predictor`` is any of the interchangeable predictors behind the cluster
    ``predictor=`` seam:

    * an object with ``annotate(requests, policy)`` — the batched, jitted
      :class:`~repro.serving.predictor.PredictorService` (trained ProD-D
      head) or the :class:`~repro.serving.predictor.PerfectOracle`; it is
      delegated to wholesale;
    * an object with ``predict(phi) -> median`` and ``quantile(phi, q)`` over
      stacked per-request features — the trained
      :class:`~repro.core.predictor.LengthPredictor` or the trace-level
      :class:`~repro.serving.arrivals.LatentOracle`;
    * ``None`` — requests pre-annotated by a trace generator keep their
      predictions; anything else falls back to max/oracle reservation.
    """
    if not requests:
        return
    if predictor is not None and hasattr(predictor, "annotate"):
        predictor.annotate(requests, policy)
        return
    if predictor is None:
        for r in requests:
            if policy.reserve == "oracle":
                r.reserve_len = float(r.true_len)
            elif (policy.reserve in ("quantile", "predicted")
                  and r.reserve_len is not None):
                # pre-annotated trace (cluster path): clamp, keep
                r.reserve_len = float(
                    min(max(r.reserve_len, 8.0), policy.max_seq_len))
            else:
                r.reserve_len = float(policy.max_seq_len)
        return

    phi = np.stack([np.asarray(r.phi) for r in requests])
    med = np.asarray(predictor.predict(phi), np.float64)
    if policy.reserve == "quantile":
        res = np.asarray(predictor.quantile(phi, policy.quantile), np.float64)
    elif policy.reserve == "predicted":
        res = med * policy.margin
    elif policy.reserve == "oracle":
        res = np.array([r.true_len for r in requests], np.float64)
    else:
        res = np.full(len(requests), policy.max_seq_len, np.float64)
    for r, m, rv in zip(requests, med, res):
        r.predicted_len = float(m)
        r.reserve_len = float(min(max(rv, 8.0), policy.max_seq_len))


def order_key(r: Request, order: str,
              max_cap: Optional[float] = None, refiner=None) -> float:
    """Static heap key realizing ``order`` (FIFO tie-break happens outside).

    EDF keys on the absolute deadline; least-laxity keys on
    ``deadline − q0.9-remaining`` (see module docstring for why the static
    key is exact). ``max_cap`` (the policy's ``max_seq_len``) lets
    :func:`quantile_remaining` recognize an uninformative ``reserve="max"``
    reservation and fall through to the point prediction; ``refiner``
    (engines with ``Policy.refine_every > 0``) keeps over-runner keys
    well-defined via posterior conditioning. Requests without a deadline
    key to +inf under both — they run FIFO after every deadline-carrying
    request."""
    if order == "fcfs":
        return float(r.arrival)
    if order in ("sjf_pred", "srtf_pred"):
        return predicted_remaining(r)
    if order == "sjf_oracle":
        return float(r.true_len)
    if order == "edf":
        return float(r.deadline) if r.deadline is not None else float("inf")
    if order == "laxity":
        if r.deadline is None:
            return float("inf")
        return float(r.deadline) - quantile_remaining(r, max_cap=max_cap,
                                                      refiner=refiner)
    raise ValueError(order)


def pick_next(queue: List[Request], policy: Policy, now: float) -> Optional[int]:
    """Index into `queue` of the next request to admit (arrived ones only)."""
    avail = [i for i, r in enumerate(queue) if r.arrival <= now]
    if not avail:
        return None
    return min(avail, key=lambda i: (order_key(queue[i], policy.order), i))
