"""Schedulers: FCFS, SJF on predicted length, and the uncertainty-aware
quantile policy that only a distributional predictor (ProD-D) enables.

Reservation policies:
* ``max``       — reserve max_seq_len (vLLM-naive; zero overflow, max waste)
* ``predicted`` — reserve predicted median × margin
* ``quantile``  — reserve the q-th quantile of the ProD-D predictive
                  distribution (per-request risk control; the CoRE-style
                  learning-for-scheduling coupling)
* ``oracle``    — reserve the realized length (upper bound)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from repro.serving.request import Request


@dataclass(frozen=True)
class Policy:
    order: str = "fcfs"            # fcfs | sjf_pred | sjf_oracle | srtf_pred
    reserve: str = "max"           # max | predicted | quantile | oracle
    margin: float = 1.2            # multiplier for `predicted`
    quantile: float = 0.9
    max_seq_len: int = 4096
    preempt: bool = False          # srtf: evict the longest-remaining active
    preempt_factor: float = 2.0    # only if its remaining > factor × newcomer's


def predicted_remaining(r: Request) -> float:
    """Estimated remaining tokens (ProD-O style: static estimate − progress)."""
    base = r.predicted_len if r.predicted_len is not None else float(r.true_len)
    return max(base - r.generated, 1.0)


def annotate_predictions(requests: List[Request], predictor, policy: Policy):
    """Attach predicted median + reservation length from the ProD head.

    ``predictor`` is anything with ``predict(phi) -> median`` and
    ``quantile(phi, q)`` over stacked per-request features — the trained
    :class:`~repro.core.predictor.LengthPredictor` or the trace-level
    :class:`~repro.serving.arrivals.LatentOracle`. Without a predictor,
    requests pre-annotated by a trace generator keep their predictions;
    anything else falls back to max/oracle reservation."""
    if not requests:
        return
    if predictor is None:
        for r in requests:
            if policy.reserve == "oracle":
                r.reserve_len = float(r.true_len)
            elif (policy.reserve in ("quantile", "predicted")
                  and r.reserve_len is not None):
                # pre-annotated trace (cluster path): clamp, keep
                r.reserve_len = float(
                    min(max(r.reserve_len, 8.0), policy.max_seq_len))
            else:
                r.reserve_len = float(policy.max_seq_len)
        return

    phi = np.stack([np.asarray(r.phi) for r in requests])
    med = np.asarray(predictor.predict(phi), np.float64)
    if policy.reserve == "quantile":
        res = np.asarray(predictor.quantile(phi, policy.quantile), np.float64)
    elif policy.reserve == "predicted":
        res = med * policy.margin
    elif policy.reserve == "oracle":
        res = np.array([r.true_len for r in requests], np.float64)
    else:
        res = np.full(len(requests), policy.max_seq_len, np.float64)
    for r, m, rv in zip(requests, med, res):
        r.predicted_len = float(m)
        r.reserve_len = float(min(max(rv, 8.0), policy.max_seq_len))


def pick_next(queue: List[Request], policy: Policy, now: float) -> Optional[int]:
    """Index into `queue` of the next request to admit (arrived ones only)."""
    avail = [i for i, r in enumerate(queue) if r.arrival <= now]
    if not avail:
        return None
    if policy.order == "fcfs":
        return min(avail, key=lambda i: queue[i].arrival)
    if policy.order in ("sjf_pred", "srtf_pred"):
        return min(avail, key=lambda i: predicted_remaining(queue[i]))
    if policy.order == "sjf_oracle":
        return min(avail, key=lambda i: queue[i].true_len)
    raise ValueError(policy.order)
