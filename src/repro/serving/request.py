"""Request model and workload generators."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    arrival: float                      # arrival time (engine steps)
    prompt_len: int
    true_len: int                       # realized decode length (sim: sampled)
    phi: Optional[np.ndarray] = None    # served-LLM hidden state (predictor input)
    predicted_len: Optional[float] = None
    reserve_len: Optional[float] = None
    # distributional predictions (attached by a PredictorService at dispatch);
    # pred_q is the q0.9 total decode length — the remaining-work signal that
    # least-laxity ordering and quantile work stealing consume
    pred_q: Optional[float] = None
    pred_probs: Optional[np.ndarray] = None  # predictive histogram over bins
    # calibrated reservation quantile recorded at annotation time by an
    # OnlineAdapter — the conformal score target (true_len <= cal_q means
    # covered). Unlike reserve_len (which eviction may bump) it changes only
    # under Policy.refine_every > 0, where each refine tick re-cuts it on the
    # posterior at the same effective level, so ACI coverage is tracked
    # against the refreshed estimate (conformal-on-posterior)
    cal_q: Optional[float] = None
    # effective CDF level the reservation was cut at, recovered once from
    # (pred_probs, cal_q) at the first refine tick — pinning it stops the
    # level from ratcheting when later refines re-invert an already-refreshed
    # cal_q against the dispatch histogram
    pred_level: Optional[float] = None
    # trace provenance (cluster simulator)
    setting: Optional[str] = None       # "model/scenario" the law came from
    deadline: Optional[float] = None    # absolute SLO: must finish by this step
    replica: Optional[int] = None       # router-assigned replica index
    # shared-context provenance: the first prefix_len prompt tokens are the
    # context named prefix_id (a chat session's accumulated turns, an agentic
    # loop's growing scratchpad, or a per-scenario system prompt). A
    # share_prefixes=True KV pool backs those tokens with ref-counted shared
    # pages, and the prefix_affine router keeps the session on the replica
    # already holding them. None/0 = no shared context (unchanged behavior)
    prefix_id: Optional[str] = None
    prefix_len: int = 0
    # engine bookkeeping
    t_start: Optional[float] = None
    t_finish: Optional[float] = None
    # tick that emitted this request's first decode token (the TTFT anchor);
    # set once — a preempted request that resumes keeps its original value
    t_first_token: Optional[float] = None
    generated: int = 0
    overflows: int = 0
    # keep-mode preemption: tokens of KV pages this (queued) request still
    # holds — page-rounded grant covering prompt + generated progress. The
    # pages live in one replica's pool; work stealing hands them off
    # (export_held/adopt_held) or drops them back to 0 (recompute)
    held: int = 0

    @property
    def wait(self) -> float:
        return (self.t_start - self.arrival) if self.t_start is not None else np.inf

    @property
    def ttft(self) -> float:
        """Time to first token (inf until one is emitted)."""
        return (self.t_first_token - self.arrival) \
            if self.t_first_token is not None else np.inf

    @property
    def latency(self) -> float:
        return (self.t_finish - self.arrival) if self.t_finish is not None else np.inf

    @property
    def slo_met(self) -> bool:
        """Finished, and within the deadline if one was set."""
        if self.t_finish is None:
            return False
        return self.deadline is None or self.t_finish <= self.deadline

    def fresh_copy(self) -> "Request":
        """Copy for a new simulation run: identity/trace fields (including
        any fields added later) carried over via :func:`dataclasses.replace`,
        engine bookkeeping reset. ``phi`` stays shared — it is read-only for
        the engine. This replaces the brittle ``Request(**r.__dict__)``
        pattern, which silently breaks on non-init fields."""
        return dataclasses.replace(self, replica=None, t_start=None,
                                   t_finish=None, t_first_token=None,
                                   generated=0, overflows=0, held=0,
                                   pred_level=None)


def workload_from_scenario(
    data, n: int, seed: int = 0, arrival_rate: float = 4.0,
) -> List[Request]:
    """Build a Poisson-arrival workload from a Track-A ScenarioData test split.

    Each request's true decode length is one *fresh* draw from its prompt's
    length distribution (sample column r-1), and φ is the last-token view —
    i.e. the predictor never saw the realized length, as in deployment.
    """
    rng = np.random.default_rng(seed)
    n = min(n, data.len_test.shape[0])
    idx = rng.permutation(data.len_test.shape[0])[:n]
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    reqs = []
    for i, (j, t) in enumerate(zip(idx, arrivals)):
        reqs.append(Request(
            rid=i, arrival=float(t),
            prompt_len=int(rng.integers(16, 256, endpoint=True)),
            true_len=int(data.len_test[j, -1]),
            phi=data.phi_test["last"][j],
        ))
    return reqs
