"""Serving substrate: requests, KV-cache reservation accounting, schedulers,
and continuous-batching engines (discrete-event simulator + real tiny-LM)."""
