"""Serving substrate: requests, KV-cache reservation accounting, schedulers,
continuous-batching engines (discrete-event simulator + real tiny-LM), and
the open-loop multi-replica cluster simulator (arrival traces + routers)."""

from repro.serving.arrivals import LatentOracle, TraceConfig, make_trace
from repro.serving.cluster import Cluster, ClusterStats, ROUTERS
from repro.serving.engine import ServeStats, SimEngine
from repro.serving.kvcache import KVCacheManager
from repro.serving.request import Request, workload_from_scenario
from repro.serving.scheduler import Policy

__all__ = [
    "Cluster", "ClusterStats", "KVCacheManager", "LatentOracle", "Policy",
    "ROUTERS", "Request", "ServeStats", "SimEngine", "TraceConfig",
    "make_trace", "workload_from_scenario",
]
