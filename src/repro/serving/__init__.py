"""Serving substrate: requests, KV-cache reservation accounting, schedulers,
continuous-batching engines (discrete-event simulator + real tiny-LM), and
the open-loop multi-replica cluster simulator (arrival traces + routers)."""

from repro.serving.arrivals import (LatentOracle, TraceConfig, make_trace,
                                    stable_rate_specs)
from repro.serving.cluster import Cluster, ClusterStats, ROUTERS, STEAL_MODES
from repro.serving.engine import ReplicaSpec, ServeStats, SimEngine
from repro.serving.kvcache import KVCacheManager
from repro.serving.request import Request, workload_from_scenario
from repro.serving.scheduler import Policy

__all__ = [
    "Cluster", "ClusterStats", "KVCacheManager", "LatentOracle", "Policy",
    "ROUTERS", "ReplicaSpec", "Request", "STEAL_MODES", "ServeStats",
    "SimEngine", "TraceConfig", "make_trace", "stable_rate_specs",
    "workload_from_scenario",
]
