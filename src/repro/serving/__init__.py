"""Serving substrate: requests, KV-cache reservation accounting (paged, with
ref-counted shared prefix pages + copy-on-write), schedulers,
continuous-batching engines (discrete-event simulator + real tiny-LM), the
open-loop multi-replica cluster simulator (arrival traces — including
shared-context session/agentic traffic — + routers), the
dispatch-time predictor service that puts the trained ProD-D head in the
loop, and the online adaptation subsystem (drift-aware traces, adaptive
conformal calibration, predictor refresh, SLO-aware admission) that closes
it. See ``docs/serving.md`` for the guide."""

from repro.serving.adaptation import (AdaptationConfig, AdmissionController,
                                      OnlineAdapter, coverage_of, refit_head)
from repro.serving.arrivals import (DriftSpec, LatentOracle, TraceConfig,
                                    corrupt_latents, make_trace,
                                    stable_rate_specs)
from repro.serving.cluster import Cluster, ClusterStats, ROUTERS, STEAL_MODES
from repro.serving.engine import ReplicaSpec, ServeStats, SimEngine
from repro.serving.kvcache import KVCacheManager
from repro.serving.predictor import (PerfectOracle, PredictorService,
                                     ServiceStats, fit_trace_head)
from repro.serving.request import Request, workload_from_scenario
from repro.serving.scheduler import ORDERINGS, PREEMPT_MODES, Policy
from repro.serving.telemetry import (EVENT_KINDS, TERMINAL_KINDS, TraceEvent,
                                     Tracer, goodput, latency_summary,
                                     percentile_summary, ttft_summary)

__all__ = [
    "AdaptationConfig", "AdmissionController", "Cluster", "ClusterStats",
    "DriftSpec", "EVENT_KINDS", "KVCacheManager", "LatentOracle", "ORDERINGS",
    "OnlineAdapter", "PREEMPT_MODES", "PerfectOracle", "Policy",
    "PredictorService", "ROUTERS", "ReplicaSpec", "Request", "STEAL_MODES",
    "ServeStats", "ServiceStats", "SimEngine", "TERMINAL_KINDS",
    "TraceConfig", "TraceEvent", "Tracer", "corrupt_latents", "coverage_of",
    "fit_trace_head", "goodput", "latency_summary", "make_trace",
    "percentile_summary", "refit_head", "stable_rate_specs", "ttft_summary",
    "workload_from_scenario",
]
