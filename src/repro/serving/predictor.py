"""Predictor service: the trained ProD-D head in the serving loop.

This module closes the paper→cluster loop. The cluster simulator historically
routed, reserved, and stole using :class:`~repro.serving.arrivals.LatentOracle`
— an analytic stand-in that inverts the length laws. Here the *actual* paper
artifact (the ProD-D head of :mod:`repro.core.heads`, fused kernel in
:mod:`repro.kernels.prod_head`) serves predictions at dispatch time:

* :class:`PredictorService` — wraps a trained
  :class:`~repro.core.predictor.LengthPredictor` behind a batched, jitted
  inference API. Requests arriving within one ``window``-step span are
  featurized (their noise-corrupted latents ARE the features, matching the
  informativeness calibration of :mod:`repro.serving.arrivals`), scored in a
  single padded-batch fused ``head_quantiles`` call (median + q0.9 + the
  policy's reservation quantile + the full histogram in one evaluation), and
  annotated onto :class:`~repro.serving.request.Request` for the router, KV
  reservation, EDF/least-laxity ordering, and work stealing to consume. A
  small LRU cache short-circuits repeated features (retried / duplicated
  prompts) without re-running the head.

* :class:`PerfectOracle` — the zero-error upper bound: "predicts" the
  realized length. Interchangeable with the service and the latent oracle at
  the ``Cluster(predictor=...)`` seam, so benchmarks can bracket the trained
  head between the analytic proxy and perfection.

* :func:`fit_trace_head` — trains a ProD-D head on repeated-generation
  targets drawn from the same calibrated heavy-tailed laws the trace
  generator uses (the paper's §2.3 protocol at trace scale), returning a
  predictor ready to drop into a :class:`PredictorService`.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request
from repro.serving.scheduler import Policy

# one jitted fused forward per kernel impl, shared across every
# PredictorService instance — jit caches key on the function object, so a
# per-instance lambda would recompile every bucket shape per instance
_FWD_CACHE: Dict[str, object] = {}


def _fused_forward(impl: str):
    fn = _FWD_CACHE.get(impl)
    if fn is None:
        import functools

        import jax

        from repro.core.heads import head_quantiles

        fn = jax.jit(functools.partial(head_quantiles, impl=impl))
        _FWD_CACHE[impl] = fn
    return fn


@dataclass
class ServiceStats:
    """Operational counters for one :class:`PredictorService` lifetime.

    ``requests`` — requests annotated; ``scored`` — requests that reached the
    head (misses); ``cache_hits`` — served from the LRU; ``batches`` — fused
    head calls; ``padded`` — wasted pad slots across those calls; ``buckets``
    — distinct compiled batch shapes (one jit compile each); ``refreshes`` —
    weight swaps installed via :meth:`PredictorService.swap_weights`.
    """

    requests: int = 0
    scored: int = 0
    cache_hits: int = 0
    batches: int = 0
    padded: int = 0
    refreshes: int = 0
    buckets: set = field(default_factory=set)

    def row(self) -> dict:
        d = self.__dict__.copy()
        d["buckets"] = sorted(self.buckets)
        d["hit_rate"] = self.cache_hits / max(self.requests, 1)
        d["mean_batch"] = self.scored / max(self.batches, 1)
        return d


class PredictorService:
    """Batched, jitted, dispatch-time inference over a trained ProD-D head.

    Parameters
    ----------
    predictor : a :class:`~repro.core.predictor.LengthPredictor` (params +
        bin edges) — typically from :func:`fit_trace_head` (trace features)
        or trained on real hidden states (``examples/serve_with_prod.py``).
    window : dispatch window in engine steps. Requests whose arrivals fall in
        the same window are scored together — one fused head call per window
        (per ``max_batch`` chunk), amortizing inference exactly the way a
        real serving frontend batches prediction at admission.
    max_batch : cap on one fused call's batch; windows larger than this are
        chunked. Batches are padded up to the next power of two (≥ 8, ≤
        ``max_batch``) so jit recompiles stay O(log max_batch), not O(traces).
    cache_size : LRU entries keyed by the feature bytes (+ quantile set); 0
        disables caching.
    work_quantile : CDF level attached as ``Request.pred_q`` — the
        pessimistic remaining-work signal least-laxity ordering and quantile
        stealing consume (paper-aligned default: q0.9).
    attach_hist : also attach the full predictive histogram as
        ``Request.pred_probs`` (float32, K bins).
    impl : kernel dispatch for the fused head — ``"auto"`` (Pallas on TPU,
        XLA elsewhere), ``"pallas"``, ``"interpret"``, or ``"xla"``.
    step_token_budget, prefill_chunk_tokens : the serving engine's
        chunked-prefill knobs (see
        :class:`~repro.serving.engine.ReplicaSpec`). When a budget is given,
        dispatch-time scoring rides the chunked batch-prefill: one engine
        step starts at most ``budget // chunk`` prompts' first chunks, so a
        fused inference batch larger than that never forms. The effective
        ``max_batch`` is capped at that lane count (power-of-two rounded,
        floor 8 to match the pad buckets). Annotation *results* are
        unchanged — prediction is deterministic in the features — only
        batching shape and :class:`ServiceStats` move.
    """

    def __init__(self, predictor, window: float = 16.0, max_batch: int = 512,
                 cache_size: int = 8192, work_quantile: float = 0.9,
                 attach_hist: bool = True, impl: str = "auto",
                 step_token_budget: Optional[int] = None,
                 prefill_chunk_tokens: int = 0, tracer=None):
        if window <= 0:
            raise ValueError("window must be positive")
        if max_batch <= 0:
            raise ValueError("max_batch must be positive")
        if step_token_budget is not None:
            if step_token_budget < 1:
                raise ValueError("step_token_budget must be >= 1")
            ce = min(prefill_chunk_tokens or step_token_budget,
                     step_token_budget)
            lanes = max(1, int(step_token_budget) // max(int(ce), 1))
            max_batch = min(int(max_batch),
                            max(8, 1 << (lanes - 1).bit_length()))
        self.step_token_budget = step_token_budget
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.predictor = predictor
        self.window = float(window)
        self.max_batch = int(max_batch)
        self.cache_size = int(cache_size)
        self.work_quantile = float(work_quantile)
        self.attach_hist = attach_hist
        self.impl = impl
        self.tracer = tracer
        self.stats = ServiceStats()
        self._cache: "OrderedDict[tuple, tuple]" = OrderedDict()

    # -- weight refresh (online adaptation) ----------------------------------

    def swap_weights(self, predictor):
        """Install re-fit head weights without losing batching/cache stats.

        The live service keeps its window/bucket/LRU configuration and
        operational counters; only the underlying
        :class:`~repro.core.predictor.LengthPredictor` changes. Cache
        hygiene: the LRU is cleared wholesale, so a stale (pre-refresh)
        prediction can never be served after a swap; ``stats.refreshes``
        counts the installed weight versions."""
        self.predictor = predictor
        self._cache.clear()
        self.stats.refreshes += 1

    # -- fused inference -----------------------------------------------------

    def _forward(self, phi: np.ndarray, qs: Tuple[float, ...]):
        """One padded-batch fused call: (n, d) -> (probs (n, K), quants
        (n, Q)). Pads to a power-of-two bucket so jit caches a bounded
        number of shapes."""
        import jax.numpy as jnp

        fwd = _fused_forward(self.impl)
        n = phi.shape[0]
        bucket = max(8, 1 << (n - 1).bit_length())
        bucket = min(bucket, self.max_batch)
        probs_out, quants_out = [], []
        for lo in range(0, n, bucket):
            chunk = phi[lo:lo + bucket]
            pad = bucket - chunk.shape[0]
            if pad:
                chunk = np.concatenate([chunk, np.zeros((pad, chunk.shape[1]),
                                                        chunk.dtype)])
            p, q = fwd(self.predictor.params,
                       jnp.asarray(chunk, jnp.float32),
                       self.predictor.edges,
                       jnp.asarray(qs, jnp.float32))
            m = bucket - pad
            probs_out.append(np.asarray(p[:m], np.float32))
            quants_out.append(np.asarray(q[:m], np.float64))
            self.stats.batches += 1
            self.stats.padded += pad
            self.stats.buckets.add(bucket)
        return np.concatenate(probs_out), np.concatenate(quants_out)

    def _qs_for(self, policy: Policy) -> Tuple[float, ...]:
        """CDF levels one dispatch batch needs: median (routing signal), the
        work quantile (laxity/steal), and the reservation quantile."""
        qs = {0.5, self.work_quantile}
        if policy.reserve == "quantile":
            qs.add(float(policy.quantile))
        return tuple(sorted(qs))

    # -- dispatch-time annotation (the cluster/engine entry point) -----------

    def annotate(self, requests: List[Request], policy: Policy):
        """Score ``requests`` in arrival-window batches and attach
        median/quantile/histogram predictions + the policy's reservation.

        Called by :func:`~repro.serving.scheduler.annotate_predictions` via
        the ``Cluster``/``SimEngine`` ``predictor=`` seam. Deterministic:
        prediction depends only on features, so window batching and caching
        cannot change simulation results — only inference cost."""
        if not requests:
            return
        qs = self._qs_for(policy)
        iq = {q: i for i, q in enumerate(qs)}
        order = sorted(range(len(requests)),
                       key=lambda i: float(requests[i].arrival))
        # split the arrival-sorted stream into dispatch windows
        windows: List[List[int]] = []
        w_end = None
        for i in order:
            t = float(requests[i].arrival)
            if w_end is None or t >= w_end:
                windows.append([])
                w_end = (np.floor(t / self.window) + 1.0) * self.window
            windows[-1].append(i)
        for win in windows:
            self._annotate_window([requests[i] for i in win], qs, iq, policy)

    def _annotate_window(self, reqs: List[Request], qs, iq, policy: Policy):
        self.stats.requests += len(reqs)
        hits0 = self.stats.cache_hits
        scored0 = self.stats.scored
        keys = []
        misses: List[int] = []
        results: List[Optional[tuple]] = [None] * len(reqs)
        for j, r in enumerate(reqs):
            if r.phi is None:
                raise ValueError(f"request {r.rid} has no features (phi)")
            key = (np.ascontiguousarray(r.phi).tobytes(), qs)
            keys.append(key)
            if self.cache_size and key in self._cache:
                self._cache.move_to_end(key)
                results[j] = self._cache[key]
                self.stats.cache_hits += 1
            else:
                misses.append(j)
        if misses:
            # dedupe identical features within the window: score once
            uniq: "OrderedDict[tuple, List[int]]" = OrderedDict()
            for j in misses:
                uniq.setdefault(keys[j], []).append(j)
            phi = np.stack([np.asarray(reqs[js[0]].phi, np.float64)
                            for js in uniq.values()])
            probs, quants = self._forward(phi, qs)
            self.stats.scored += phi.shape[0]
            for row, (key, js) in enumerate(uniq.items()):
                hit = (quants[row], probs[row])
                for j in js:
                    results[j] = hit
                if self.cache_size:
                    self._cache[key] = hit
                    if len(self._cache) > self.cache_size:
                        self._cache.popitem(last=False)
        for r, res in zip(reqs, results):
            quant, probs = res
            r.predicted_len = float(quant[iq[0.5]])
            r.pred_q = float(quant[iq[self.work_quantile]])
            if self.attach_hist:
                r.pred_probs = probs
            if policy.reserve == "quantile":
                rv = float(quant[iq[float(policy.quantile)]])
            elif policy.reserve == "predicted":
                rv = r.predicted_len * policy.margin
            elif policy.reserve == "oracle":
                rv = float(r.true_len)
            else:
                rv = float(policy.max_seq_len)
            r.reserve_len = float(min(max(rv, 8.0), policy.max_seq_len))
        if self.tracer is not None:
            # one event per fused inference window, keyed to the window's
            # first arrival (the time the batch formed)
            self.tracer.emit(float(reqs[0].arrival), -1, -1, "predict",
                             n=len(reqs),
                             hits=self.stats.cache_hits - hits0,
                             scored=self.stats.scored - scored0)

    # -- raw predictor protocol (interchangeability) -------------------------

    def predict(self, phi) -> np.ndarray:
        """Point (median) predictions for stacked features — the unbatched
        predictor protocol, so a service can stand anywhere a
        :class:`~repro.serving.arrivals.LatentOracle` does."""
        _, quants = self._forward(np.asarray(phi, np.float64), (0.5,))
        return quants[:, 0]

    def quantile(self, phi, q: float) -> np.ndarray:
        """Interpolated predictive q-quantiles for stacked features."""
        _, quants = self._forward(np.asarray(phi, np.float64), (float(q),))
        return quants[:, 0]


class PerfectOracle:
    """Zero-error predictor: annotates each request with its realized length.

    The upper bound every predictor row is measured against — plugs into the
    same ``predictor=`` seam as :class:`PredictorService` and
    :class:`~repro.serving.arrivals.LatentOracle`. Under ``reserve="max"``
    it still reserves the policy cap (the reservation rule, not the
    prediction, is what ``max`` ablates)."""

    def annotate(self, requests: List[Request], policy: Policy):
        """Attach ``true_len`` as median, q0.9, and (non-max) reservation."""
        for r in requests:
            tl = float(r.true_len)
            r.predicted_len = tl
            r.pred_q = tl
            rv = float(policy.max_seq_len) if policy.reserve == "max" else tl
            r.reserve_len = float(min(max(rv, 8.0), policy.max_seq_len))


def fit_trace_head(cfg, n_train: int = 4000, r: int = 16, n_bins: int = 32,
                   hidden: int = 128, epochs: int = 25, seed: int = 1234,
                   verbose: bool = False):
    """Train a ProD-D head for traces generated by ``cfg`` (a
    :class:`~repro.serving.arrivals.TraceConfig`).

    The paper's §2.3 protocol at trace scale: per training prompt, draw ``r``
    independent lengths from its heavy-tailed law, bin them into a histogram
    target (ProD-D), and fit the shared 2-layer head on the *noise-corrupted*
    latents — the exact feature distribution trace requests carry, so serving
    error honestly reflects the per-scenario informativeness calibration.
    Bins are log-spaced up to ``cfg.max_seq_len`` (constant relative
    resolution under heavy tails).

    Returns a :class:`~repro.core.predictor.LengthPredictor` ready for
    :class:`PredictorService`. Deterministic in ``(cfg, seed)`` and
    independent of the trace seed — the head never sees the served trace.
    """
    import jax
    import jax.numpy as jnp

    from repro.common.config import PredictorConfig
    from repro.core import bins as bins_mod
    from repro.core import targets as targets_mod
    from repro.core.predictor import train_predictor
    from repro.data.lengths import sample_lengths, sample_prompt_latents
    from repro.data.scenarios import get_spec
    from repro.serving.arrivals import corrupt_latents

    rng = np.random.default_rng(seed)
    settings = cfg.settings()
    pick = rng.integers(0, len(settings), size=n_train)
    phi = np.zeros((n_train, 4), np.float64)
    lens = np.zeros((n_train, r), np.int64)
    for si, (model, scen) in enumerate(settings):
        idx = np.nonzero(pick == si)[0]
        if len(idx) == 0:
            continue
        spec = get_spec(model, scen)
        lat = sample_prompt_latents(rng, spec.law, len(idx))
        lens[idx] = sample_lengths(rng, lat, r, spec.law)
        phi[idx] = corrupt_latents(rng, lat, spec, cfg.view)
    lens = np.minimum(lens, cfg.max_seq_len)

    pcfg = PredictorConfig(n_bins=n_bins, hidden=hidden, bin_spacing="log",
                           bin_max=float(cfg.max_seq_len), target="dist",
                           r_samples=r, epochs=epochs, seed=seed)
    edges = bins_mod.make_edges(pcfg.n_bins, pcfg.bin_max, pcfg.bin_spacing)
    tgt = targets_mod.dist_target(jnp.asarray(lens, jnp.float32), edges)
    return train_predictor(jax.random.PRNGKey(seed), jnp.asarray(phi), tgt,
                           pcfg, edges, verbose=verbose)
