"""Serving telemetry: per-request event tracing, time-series gauges, and
exporters (Chrome/Perfetto timeline, Prometheus text, JSON summary).

The end-of-run aggregates (``ServeStats``/``ClusterStats``) summarize a run
to scalars, which is exactly what heavy-tailed workloads punish: a p99
regression, a coverage erosion under drift, or a preemption cascade are only
diagnosable from *when* things happened. The :class:`Tracer` is the
answer — an optional observer threaded through the serving stack via the
``tracer=`` seam on :class:`~repro.serving.engine.SimEngine`,
:class:`~repro.serving.cluster.Cluster`,
:class:`~repro.serving.adaptation.AdmissionController`,
:class:`~repro.serving.adaptation.OnlineAdapter`, and
:class:`~repro.serving.predictor.PredictorService`.

Design constraints (tested in ``tests/test_telemetry.py``):

* ``tracer=None`` is **bit-identical** to a tracer-less build — every hook
  is an ``if tracer is not None`` branch that reads state without mutating
  any simulation arithmetic (golden-pinned engine + cluster rows).
* Trace-on emits **identical event streams** from the per-slot reference
  decode path and the vectorized event-leap path. Ticks inside a leap are
  provably eventless except for first tokens, which the leap synthesizes
  from canonicalized slot state at the leap boundary (the same
  ``t + 1.0`` timestamp the per-tick loop would assign); gauge sampling
  ticks are *evented* (``ticks_to_event`` caps at the next sample tick,
  like refine ticks), so both paths sample the same state at the same
  ticks. Raw buffer order can differ across paths (a leap emits future
  first tokens early), so stream equality is defined over
  :meth:`Tracer.canonical` — a total order on
  ``(t, replica, rid, kind, data)``.

Event schema — ``TraceEvent(t, replica, rid, kind, data)`` with ``data`` a
sorted tuple of ``(key, value)`` pairs (see ``docs/observability.md`` for
the full field tables):

========== ============================================================
kind        emitted when
========== ============================================================
arrival     a request enters the system (cluster dispatch / engine run)
routed      the router picked a replica (``to``)
admission   the admission controller evaluated a request (``ok``, ``eta``)
rejected    admission declined it (terminal)
refine      a posterior refresh touched an active slot (``action``)
held_release a queued keep-mode holder's pages were sacrificed
admitted    a slot started (``grant`` tokens, ``pf`` ticks/tokens,
            ``resumed`` flag)
prefill_chunk a budget-mode prefill chunk was consumed (``take``, ``left``)
first_token the slot emitted its first token
oom_evict   the stall breaker recompute-preempted a slot
preempted   SRTF preemption (``kept`` tokens, ``mode``)
stolen      a rebalance migrated a queued request (``frm``, ``to``,
            ``pages``, ``delay``)
refresh     the online adapter hot-swapped head weights
predict     the predictor service scored one dispatch window (``n``,
            ``hits``, ``scored``)
finish      the request completed (``gen``, ``slo_ok``) — terminal
timeout     its deadline expired while queued — terminal
dropped     it proved unservable — terminal
========== ============================================================

Conservation invariant: every submitted request's stream is well-ordered
(arrival <= routed <= admitted <= first_token <= finish) and ends in exactly
one terminal kind, with ``submitted == finish + timeout + rejected +
dropped`` (:meth:`Tracer.terminal_counts`).

This module also owns the shared percentile summarization
(:func:`latency_summary` / :func:`ttft_summary` / :func:`goodput`) that
``engine.py`` and ``cluster.py`` both delegate to — one implementation, one
set of column names.
"""

from __future__ import annotations

import json
from collections import Counter, OrderedDict, deque
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "TraceEvent", "Tracer", "EVENT_KINDS", "TERMINAL_KINDS",
    "latency_summary", "ttft_summary", "goodput", "percentile_summary",
]


# ---------------------------------------------------------------------------
# shared percentile summarization (the one implementation ServeStats and
# ClusterStats both use — see tests/test_telemetry.py::TestSharedSummaries)
# ---------------------------------------------------------------------------

_PCTS = ((0.5, "p50"), (0.9, "p90"), (0.99, "p99"))


def percentile_summary(values: Sequence[float], prefix: str) -> dict:
    """``{mean_<prefix>, p50_<prefix>, p90_<prefix>, p99_<prefix>}`` over
    ``values`` — all ``inf`` when empty (no sample ≠ zero)."""
    arr = np.array(list(values), float)
    if arr.size == 0:
        inf = float("inf")
        return {f"mean_{prefix}": inf,
                **{f"{name}_{prefix}": inf for _, name in _PCTS}}
    out = {f"mean_{prefix}": float(arr.mean())}
    for q, name in _PCTS:
        out[f"{name}_{prefix}"] = float(np.quantile(arr, q))
    return out


def latency_summary(done: Sequence) -> dict:
    """End-to-end latency percentiles + mean queueing wait over completed
    requests (``inf`` when none completed)."""
    out = percentile_summary([r.latency for r in done], "latency")
    if done:
        out["mean_wait"] = float(np.array([r.wait for r in done]).mean())
    else:
        out["mean_wait"] = float("inf")
    return out


def ttft_summary(done: Sequence) -> dict:
    """Time-to-first-token percentiles over completed requests that emitted
    at least one token (degenerate zero-length requests carry no sample)."""
    return percentile_summary([r.t_first_token - r.arrival for r in done
                               if r.t_first_token is not None], "ttft")


def goodput(done: Sequence, makespan: float) -> float:
    """Within-SLO completed tokens per step."""
    toks = sum(r.true_len for r in done if r.slo_met)
    return toks / max(makespan, 1.0)


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------


class TraceEvent(NamedTuple):
    t: float
    replica: int
    rid: int
    kind: str
    data: tuple     # sorted (key, value) pairs — hashable, order-comparable


# Lifecycle rank: the canonical within-(t, replica, rid) order. Only ranks
# that can collide on one tick for one request matter (e.g. first_token
# before finish, refine before first_token, held_release before a same-tick
# re-admission); the rest just make the total order stable.
EVENT_KINDS = ("arrival", "routed", "admission", "rejected", "refine",
               "held_release", "admitted", "prefill_chunk", "first_token",
               "oom_evict", "preempted", "stolen", "refresh", "predict",
               "finish", "timeout", "dropped")
_RANK = {k: i for i, k in enumerate(EVENT_KINDS)}

TERMINAL_KINDS = ("finish", "timeout", "rejected", "dropped")


class Tracer:
    """Structured serving telemetry: a bounded event ring + periodic gauges.

    Parameters
    ----------
    capacity : ring-buffer size in events; older events are evicted FIFO
        (``emitted`` keeps the true total, so overflow is never silent).
    sample_every : record time-series gauges every ``k`` ticks (0 disables
        sampling; events are always recorded). Sampling ticks become
        *evented* in the vectorized engine so both decode paths sample
        identical state — heavier sampling therefore shortens leaps.
    residual_window : per-scenario-class rolling window of
        predicted-vs-realized residuals feeding the live histograms.
    residual_edges : bin edges for those histograms (tokens of signed
        residual ``true − predicted``); defaults to symmetric powers of two.
    """

    def __init__(self, capacity: int = 1_000_000, sample_every: int = 0,
                 residual_window: int = 512,
                 residual_edges: Optional[Sequence[float]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if sample_every < 0:
            raise ValueError("sample_every must be >= 0")
        self.capacity = int(capacity)
        self.sample_every = int(sample_every)
        self.residual_window = int(residual_window)
        if residual_edges is None:
            residual_edges = [-512.0, -128.0, -32.0, -8.0, 0.0,
                              8.0, 32.0, 128.0, 512.0]
        self.residual_edges = [float(e) for e in residual_edges]
        self.events: deque = deque(maxlen=self.capacity)
        self.emitted = 0                       # total, incl. ring-evicted
        self.counts: Counter = Counter()       # by kind, never evicted
        self.series: List[dict] = []           # gauge samples (dict rows)
        self.residual_series: List[dict] = []  # per-class histogram snapshots
        self._res: "OrderedDict[str, deque]" = OrderedDict()
        self._res_cov: Dict[str, deque] = {}
        self._last_refines: Dict[int, Tuple[int, int]] = {}

    # -- events --------------------------------------------------------------

    def emit(self, t: float, replica: int, rid: int, kind: str, **data):
        self.events.append(TraceEvent(
            float(t), int(replica), int(rid), kind,
            tuple(sorted(data.items()))))
        self.emitted += 1
        self.counts[kind] += 1

    def canonical(self) -> List[TraceEvent]:
        """The events in their canonical total order — ``(t, replica, rid,
        lifecycle rank, data)``. Leaps emit synthesized first tokens ahead
        of wall-clock order, so raw buffer order is path-dependent; this
        order is not, and is what the vec-vs-ref equality tests compare."""
        return sorted(self.events,
                      key=lambda e: (e.t, e.replica, e.rid,
                                     _RANK.get(e.kind, len(_RANK)), e.data))

    def by_rid(self) -> Dict[int, List[TraceEvent]]:
        """Canonical per-request streams (cluster/engine events with
        ``rid < 0`` — predict windows, refreshes — are skipped)."""
        out: Dict[int, List[TraceEvent]] = {}
        for e in self.canonical():
            if e.rid >= 0:
                out.setdefault(e.rid, []).append(e)
        return out

    def terminal_counts(self) -> Dict[str, int]:
        """Terminal-kind totals for the conservation invariant
        ``submitted == finish + timeout + rejected + dropped``. ``oom_evict``
        re-queues (non-terminal), but its unservable escalation also emits
        ``dropped``; a request preempted/stolen any number of times still
        terminates exactly once."""
        return {k: self.counts.get(k, 0) for k in TERMINAL_KINDS}

    # -- residual histograms (calibration drift, live) -----------------------

    def observe_residual(self, req):
        """Record a completed request's signed residual (realized − current
        predicted length) and its reservation-coverage indicator into the
        per-scenario-class rolling windows."""
        if req.predicted_len is None:
            return
        cls = req.setting or "?"
        win = self._res.get(cls)
        if win is None:
            win = self._res[cls] = deque(maxlen=self.residual_window)
            self._res_cov[cls] = deque(maxlen=self.residual_window)
        win.append(float(req.true_len) - float(req.predicted_len))
        bound = req.cal_q if req.cal_q is not None else req.reserve_len
        self._res_cov[cls].append(
            1.0 if bound is not None and float(req.true_len)
            <= float(bound) + 1e-9 else 0.0)

    def _snapshot_residuals(self, t: float):
        for cls, win in self._res.items():
            if not win:
                continue
            hist, _ = np.histogram(np.array(win), bins=self.residual_edges)
            under = int(np.sum(np.array(win) < self.residual_edges[0]))
            over = int(np.sum(np.array(win) >= self.residual_edges[-1]))
            self.residual_series.append({
                "t": float(t), "class": cls, "n": len(win),
                "counts": [under] + [int(c) for c in hist] + [over],
                "coverage": float(np.mean(self._res_cov[cls])),
                "mean_residual": float(np.mean(win)),
            })

    # -- gauges --------------------------------------------------------------

    def sample_engine(self, engine, t: float):
        """One per-replica gauge row, read from engine state at the top of a
        sample tick (both decode paths reach here with bit-identical state,
        so the series is path-independent)."""
        kv = engine.kv
        n = engine._n_active
        spec = engine.spec
        row = {
            "t": float(t), "replica": int(engine.replica_id),
            "kv_occupancy": kv.reserved_now / max(kv.capacity_tokens, 1),
            "kv_frag": (1.0 - kv.asked_now / kv.reserved_now)
            if kv.reserved_now else 0.0,
            "kv_amplification": (kv.logical_now / kv.reserved_now)
            if kv.reserved_now else 1.0,
            "queue_depth": len(engine._ready) + len(engine._future),
            "active_slots": int(n),
            "slot_util": n / max(engine.max_slots, 1),
            "held_tokens": int(engine._held_tokens),
            "refine_shrinks": int(engine.refine_shrinks),
            "refine_grows": int(engine.refine_grows),
        }
        last = self._last_refines.get(engine.replica_id, (0, 0))
        row["refine_shrink_rate"] = row["refine_shrinks"] - last[0]
        row["refine_grow_rate"] = row["refine_grows"] - last[1]
        self._last_refines[engine.replica_id] = (row["refine_shrinks"],
                                                 row["refine_grows"])
        if engine._budget is not None:
            # demand the *next* tick would put on the shared token budget —
            # a pure state function, so it is identical across decode paths
            # (the realized per-tick spend is not recorded during leaps)
            chunk = engine._chunk or int(engine._budget)
            pftok = engine._a_pftok[:n]
            pf_demand = int(np.minimum(pftok, chunk).sum())
            dec = pftok == 0
            dec_demand = int(np.minimum(
                spec.speed,
                (engine._a_tlen[:n] - engine._a_gen[:n])[dec]).sum())
            row["budget_util"] = min(pf_demand + dec_demand,
                                     int(engine._budget)) / int(engine._budget)
        self.series.append(row)

    def sample_cluster(self, cluster, t: float):
        """One fleet-level gauge row (``replica=-1``): aggregate queue/KV
        state, predictor-service cache hit rate, rolling conformal coverage
        — plus a snapshot of every per-class residual histogram."""
        engines = cluster.engines
        reserved = sum(e.kv.reserved_now for e in engines)
        capacity = sum(e.kv.capacity_tokens for e in engines)
        asked = sum(e.kv.asked_now for e in engines)
        row = {
            "t": float(t), "replica": -1,
            "kv_occupancy": reserved / max(capacity, 1),
            "kv_frag": (1.0 - asked / reserved) if reserved else 0.0,
            "queue_depth": sum(len(e._ready) + len(e._future)
                               for e in engines),
            "active_slots": sum(e._n_active for e in engines),
            "stolen": int(cluster.stolen),
            "rejected": len(cluster.rejected_requests),
        }
        svc = cluster.predictor
        adapter = svc if hasattr(svc, "observe") else None
        if adapter is not None:
            row["rolling_coverage"] = adapter.rolling_coverage()
            row["q_eff"] = adapter.q_eff if adapter.q_eff is not None \
                else float("nan")
            row["refreshes"] = int(adapter.refreshes)
            svc = adapter.base
        stats = getattr(svc, "stats", None)
        if stats is not None:
            row["predictor_hit_rate"] = stats.cache_hits / stats.requests \
                if stats.requests else 0.0
            row["predictor_batches"] = int(stats.batches)
        self.series.append(row)
        self._snapshot_residuals(t)

    # -- exporters -----------------------------------------------------------

    def summary(self) -> dict:
        """JSON-ready run summary: event totals, terminal reconciliation,
        the gauge series, and the residual-histogram series."""
        return {
            "emitted": self.emitted,
            "buffered": len(self.events),
            "evicted": self.emitted - len(self.events),
            "counts": dict(sorted(self.counts.items())),
            "terminal": self.terminal_counts(),
            "sample_every": self.sample_every,
            "series": self.series,
            "residual_edges": self.residual_edges,
            "residuals": self.residual_series,
        }

    def write_summary(self, path: str) -> dict:
        out = self.summary()
        with open(path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition: event totals as counters, the latest
        gauge row per replica as gauges, and the latest per-class residual
        coverage."""
        lines = ["# HELP serving_events_total lifecycle events by kind",
                 "# TYPE serving_events_total counter"]
        for kind in EVENT_KINDS:
            if kind in self.counts:
                lines.append(f'serving_events_total{{kind="{kind}"}} '
                             f'{self.counts[kind]}')
        latest: "OrderedDict[int, dict]" = OrderedDict()
        for row in self.series:
            latest[row["replica"]] = row
        gauges = sorted({k for row in latest.values() for k in row
                         if k not in ("t", "replica")})
        for g in gauges:
            lines.append(f"# TYPE serving_{g} gauge")
            for rep, row in latest.items():
                if g in row:
                    val = row[g]
                    lines.append(f'serving_{g}{{replica="{rep}"}} '
                                 f'{float(val)}')
        latest_res: "OrderedDict[str, dict]" = OrderedDict()
        for row in self.residual_series:
            latest_res[row["class"]] = row
        if latest_res:
            lines.append("# TYPE serving_residual_coverage gauge")
            for cls, row in latest_res.items():
                lines.append(
                    f'serving_residual_coverage{{class="{cls}"}} '
                    f'{row["coverage"]}')
        return "\n".join(lines) + "\n"

    def to_perfetto(self) -> dict:
        """Chrome/Perfetto trace-event JSON: one process per replica, lanes
        (threads) packed by greedy interval assignment, an ``X`` span per
        slot residency — split into ``prefill`` and ``decode`` phases at the
        first token — instant markers for preempt/steal/timeout/drop/refine,
        and counter tracks from the gauge series. Load the dict (or the
        file :meth:`write_perfetto` writes) in https://ui.perfetto.dev.

        Tick times are exported as microseconds 1:1 (``displayTimeUnit``
        stays ``ms`` so one engine tick renders as 1 us)."""
        ev: List[dict] = []
        episodes: Dict[int, List[tuple]] = {}   # replica -> (start, end, ...)
        open_ep: Dict[int, tuple] = {}          # rid -> (t0, replica, first)
        instants = {"preempted": "preempt", "stolen": "steal",
                    "oom_evict": "oom_evict", "timeout": "timeout",
                    "dropped": "drop", "rejected": "reject",
                    "refine": "refine", "held_release": "held_release"}
        end_t = 0.0
        for e in self.canonical():
            end_t = max(end_t, e.t)
            if e.kind == "admitted":
                open_ep[e.rid] = (e.t, e.replica, None)
            elif e.kind == "first_token" and e.rid in open_ep:
                t0, rep, _ = open_ep[e.rid]
                open_ep[e.rid] = (t0, rep, e.t)
            elif e.kind in ("finish", "preempted", "oom_evict") \
                    and e.rid in open_ep:
                t0, rep, first = open_ep.pop(e.rid)
                episodes.setdefault(rep, []).append(
                    (t0, max(e.t, t0), e.rid, first, e.kind))
            if e.kind in instants:
                ev.append({"name": instants[e.kind], "cat": "lifecycle",
                           "ph": "i", "ts": e.t, "s": "t",
                           "pid": max(e.replica, 0), "tid": 0,
                           "args": {"rid": e.rid, **dict(e.data)}})
        for rid, (t0, rep, first) in open_ep.items():   # still active at end
            episodes.setdefault(rep, []).append(
                (t0, end_t, rid, first, "open"))
        for rep in sorted(episodes):
            ev.append({"name": "process_name", "ph": "M", "pid": rep,
                       "args": {"name": f"replica {rep}"}})
            lanes: List[float] = []     # lane -> busy-until
            for t0, t1, rid, first, endk in sorted(episodes[rep]):
                lane = next((i for i, busy in enumerate(lanes)
                             if busy <= t0), None)
                if lane is None:
                    lane = len(lanes)
                    lanes.append(0.0)
                    ev.append({"name": "thread_name", "ph": "M", "pid": rep,
                               "tid": lane + 1,
                               "args": {"name": f"slot lane {lane}"}})
                lanes[lane] = t1
                split = first if first is not None and t0 < first <= t1 \
                    else None
                spans = [("prefill", t0, split), ("decode", split, t1)] \
                    if split is not None else [("decode", t0, t1)]
                for name, a, b in spans:
                    if b > a:
                        ev.append({"name": f"{name} rid={rid}",
                                   "cat": "request", "ph": "X", "ts": a,
                                   "dur": b - a, "pid": rep, "tid": lane + 1,
                                   "args": {"rid": rid, "end": endk}})
        counter_keys = ("kv_occupancy", "queue_depth", "budget_util",
                        "rolling_coverage", "predictor_hit_rate")
        for row in self.series:
            pid = max(row["replica"], 0)
            scope = "fleet" if row["replica"] < 0 else "kv"
            for key in counter_keys:
                if key in row:
                    v = float(row[key])
                    if np.isnan(v):
                        continue
                    ev.append({"name": f"{scope}:{key}", "ph": "C",
                               "ts": row["t"], "pid": pid,
                               "args": {key: v}})
        return {"traceEvents": ev, "displayTimeUnit": "ms",
                "otherData": {"emitted": self.emitted,
                              "evicted": self.emitted - len(self.events)}}

    def write_perfetto(self, path: str) -> dict:
        out = self.to_perfetto()
        with open(path, "w") as f:
            json.dump(out, f)
        return out
