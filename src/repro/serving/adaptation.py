"""Online adaptation: the closed feedback loop from observed completions back
into prediction and admission while the cluster runs.

The open-loop simulator annotates every request once, up front, with a
predictor whose calibration is frozen at fit time. Under a *moving* workload
(:class:`~repro.serving.arrivals.DriftSpec`) that reservation quantile
silently loses coverage — the lengths drift out from under the head while φ
looks unchanged. This module closes the loop with three cooperating pieces,
all living behind the same ``Cluster(predictor=...)`` seam:

* :class:`OnlineAdapter` — wraps any predictor (LatentOracle, trained
  :class:`~repro.serving.predictor.PredictorService`, PerfectOracle) and
  (1) annotates requests *at dispatch time* with an **adaptive-conformal**
  effective reservation quantile: an ACI-style step update
  ``q ← q + γ·(err − (1 − target))`` per observed completion drives realized
  coverage to ``target_coverage`` whatever the base predictor's bias is;
  (2) keeps a rolling residual window over (calibrated quantile, realized
  length) pairs for coverage/MAE **drift alarms**; and (3) periodically (or
  on alarm) **refreshes** the trained head: a warm-start re-fit on the
  recent completion buffer, hot-swapped into the live service via
  :meth:`~repro.serving.predictor.PredictorService.swap_weights` without
  losing its batching/cache stats.

* :class:`AdmissionController` — SLO-aware admission at the cluster enqueue
  seam: a request whose calibrated q-reservation cannot meet its deadline
  given the target replica's current predicted backlog is **rejected
  early** (counted in ``ClusterStats.rejected``) instead of timing out late
  after occupying queue space.

* :func:`refit_head` — the refresh primitive: ProD-D targets from single
  realized lengths (one-hot histograms; serving feedback has no repeated
  draws) trained from the current weights for a few epochs.

Determinism: the cluster feeds the adapter at fixed ``every``-tick
checkpoints with completions in a canonical global order, so the whole
closed loop stays bit-identical between the per-slot reference and
vectorized event-leap engine paths (see ``tests/test_adaptation.py``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.serving.request import Request
from repro.serving.scheduler import (Policy, annotate_predictions,
                                     quantile_remaining)


@dataclass(frozen=True)
class AdaptationConfig:
    """Knobs for one :class:`OnlineAdapter`.

    Parameters
    ----------
    target_coverage : realized-coverage target for the reservation quantile
        (P[true length ≤ reserved quantile] the controller steers to).
    gamma : ACI step size on the effective quantile level per observation.
        0 freezes the quantile — the "static" ablation that still records
        coverage through the identical code path.
    q_min, q_max : clamp range for the effective quantile level.
    window : rolling residual window (completions) for coverage/MAE
        reporting and drift alarms.
    every : cluster ticks between adaptation checkpoints (observe + refresh
        checks). The cluster caps its event leaps at these ticks, so the
        cadence is exact in both decode paths.
    refresh_every : ticks between scheduled warm-start re-fits (0 disables
        scheduled refreshes; alarms may still fire one).
    refresh_min_samples : completion-buffer floor below which no refit runs.
    refresh_epochs : warm-start epochs per refit (incremental, not
        from-scratch).
    refresh_seed : base seed for refits (advanced per refresh, so replays
        are deterministic).
    buffer_size : completion buffer capacity (most recent kept).
    coverage_alarm : drift alarm when the rolling coverage drops below
        ``target_coverage − coverage_alarm`` over a full window (0 = off).
    mae_alarm_mult : drift alarm when the rolling MAE exceeds this multiple
        of the post-warmup baseline MAE (0 = off).
    """

    target_coverage: float = 0.9
    gamma: float = 0.02
    q_min: float = 0.5
    q_max: float = 0.995
    window: int = 512
    every: int = 32
    refresh_every: float = 0.0
    refresh_min_samples: int = 256
    refresh_epochs: int = 3
    refresh_seed: int = 97
    buffer_size: int = 4096
    coverage_alarm: float = 0.0
    mae_alarm_mult: float = 0.0

    def __post_init__(self):
        if not 0.0 < self.target_coverage < 1.0:
            raise ValueError("target_coverage must be in (0, 1)")
        if self.gamma < 0:
            raise ValueError("gamma must be >= 0")
        if not 0.0 < self.q_min <= self.q_max < 1.0:
            raise ValueError("need 0 < q_min <= q_max < 1")
        if self.window <= 0 or self.every <= 0:
            raise ValueError("window and every must be positive")
        if self.buffer_size <= 0 or self.refresh_min_samples <= 0:
            raise ValueError("buffer sizes must be positive")


def coverage_of(requests, since: Optional[float] = None) -> float:
    """Realized reservation coverage over completed requests: the fraction
    whose true length fit the calibrated quantile recorded at annotation
    time (``Request.cal_q``). ``since`` restricts to requests that arrived
    at/after that step (post-switch coverage). NaN when nothing is scored.

    This is THE coverage semantics of the subsystem — the same comparison
    (with the same float tolerance) :meth:`OnlineAdapter.observe` scores, so
    benches/tests/examples can never drift from what the controller steers.

    Under ``Policy.refine_every > 0`` the engine re-cuts ``cal_q`` on the
    posterior (same effective level, recovered from the dispatch histogram),
    so coverage — and therefore the ACI feedback — is tracked against the
    *refreshed* reservation rather than the stale dispatch-time one
    (conformal-on-posterior; see ``docs/serving.md``).
    """
    scored = [r for r in requests
              if r.cal_q is not None
              and (since is None or r.arrival >= since)]
    if not scored:
        return float("nan")
    return float(np.mean([r.true_len <= r.cal_q + 1e-9 for r in scored]))


def refit_head(predictor, phi: np.ndarray, lengths: np.ndarray,
               epochs: int = 3, seed: int = 0, verbose: bool = False):
    """Warm-start re-fit of a ProD-D head on observed (φ, length) pairs.

    Serving feedback yields ONE realized length per request — not the
    paper's r repeated draws — so each completion contributes a one-hot
    histogram target; across the buffer the head still learns the smoothed
    conditional distribution because nearby features populate nearby bins.
    Training starts from the predictor's CURRENT weights and runs ``epochs``
    passes (no cold-start step floor): a cheap incremental update sized for
    the serving loop. Returns a new
    :class:`~repro.core.predictor.LengthPredictor` on the same bin edges.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import targets as targets_mod
    from repro.core.predictor import train_predictor

    lens = np.asarray(lengths, np.float64).reshape(-1, 1)
    tgt = targets_mod.dist_target(jnp.asarray(lens, jnp.float32),
                                  predictor.edges)
    pcfg = dataclasses.replace(predictor.pcfg, epochs=int(epochs))
    return train_predictor(jax.random.PRNGKey(seed),
                           jnp.asarray(np.asarray(phi), jnp.float32), tgt,
                           pcfg, predictor.edges, verbose=verbose,
                           init_params=predictor.params)


class OnlineAdapter:
    """Adaptive-conformal calibration + predictor refresh behind the
    ``Cluster(predictor=...)`` seam.

    Satisfies the ``annotate(requests, policy)`` predictor protocol (any
    base predictor composes unchanged underneath) and additionally exposes
    ``observe``/``maybe_refresh`` — the presence of ``observe`` is what
    switches :meth:`~repro.serving.cluster.Cluster.run` into its closed
    loop: dispatch-time annotation, canonical-order completion feedback at
    ``cfg.every``-tick checkpoints, and weight refreshes.

    The effective quantile level initializes lazily from the first policy's
    ``quantile`` (so ``gamma=0`` reproduces the un-adapted run exactly) and
    is only meaningful for ``reserve="quantile"`` policies; other reserve
    rules pass through, with coverage still recorded against whatever was
    reserved.
    """

    def __init__(self, base, cfg: AdaptationConfig = AdaptationConfig(),
                 tracer=None):
        self.base = base
        self.cfg = cfg
        self.tracer = tracer
        # snapshot the pristine weights: refreshes swap new predictors into
        # the live service, and a later run must not silently start from
        # run 1's refreshed head (Cluster.run guarantees deterministic
        # replay — engines reset, requests fresh-copied, adapter reset)
        self._base_predictor = getattr(base, "predictor", None)
        self.reset()

    def reset(self):
        """Clear all adaptation state (a Cluster run starts fresh),
        restoring the base service's original weights if refreshes swapped
        them out."""
        if (self._base_predictor is not None
                and self.base.predictor is not self._base_predictor):
            self.base.swap_weights(self._base_predictor)
        c = self.cfg
        self.q_eff: Optional[float] = None
        self.annotated = 0
        self.observed = 0
        self.miscovered = 0
        self.refreshes = 0
        self._cov_win: deque = deque(maxlen=c.window)
        self._mae_win: deque = deque(maxlen=c.window)
        self._mae_baseline: Optional[float] = None
        self._buf_phi: deque = deque(maxlen=c.buffer_size)
        self._buf_len: deque = deque(maxlen=c.buffer_size)
        self._last_refresh = 0.0

    # -- predictor protocol (annotation) -------------------------------------

    def annotate(self, requests: List[Request], policy: Policy):
        """Annotate via the base predictor at the current effective
        reservation quantile, recording each request's calibrated quantile
        (``cal_q``) for later conformal scoring."""
        if not requests:
            return
        c = self.cfg
        if self.q_eff is None:
            self.q_eff = float(np.clip(policy.quantile, c.q_min, c.q_max))
        eff = policy
        if policy.reserve == "quantile" and policy.quantile != self.q_eff:
            eff = dataclasses.replace(policy, quantile=self.q_eff)
        annotate_predictions(requests, self.base, eff)
        for r in requests:
            r.cal_q = float(r.reserve_len)
        self.annotated += len(requests)

    # -- feedback ------------------------------------------------------------

    def observe(self, finished: List[Request]):
        """Feed realized completions back: per-observation ACI step on the
        effective quantile, rolling residual windows, completion buffer."""
        c = self.cfg
        for r in finished:
            if r.cal_q is None:
                continue
            covered = float(r.true_len) <= r.cal_q + 1e-9
            self.observed += 1
            self.miscovered += 0 if covered else 1
            self._cov_win.append(1.0 if covered else 0.0)
            if r.predicted_len is not None:
                self._mae_win.append(
                    abs(float(r.predicted_len) - float(r.true_len)))
                if (self._mae_baseline is None
                        and len(self._mae_win) == c.window):
                    self._mae_baseline = float(np.mean(self._mae_win))
            if r.phi is not None:
                self._buf_phi.append(np.asarray(r.phi, np.float64))
                self._buf_len.append(float(r.true_len))
            if c.gamma > 0.0 and self.q_eff is not None:
                err = 0.0 if covered else 1.0
                self.q_eff = float(np.clip(
                    self.q_eff + c.gamma * (err - (1.0 - c.target_coverage)),
                    c.q_min, c.q_max))

    # -- drift detection + refresh -------------------------------------------

    def rolling_coverage(self) -> float:
        return float(np.mean(self._cov_win)) if self._cov_win else float("nan")

    def rolling_mae(self) -> float:
        return float(np.mean(self._mae_win)) if self._mae_win else float("nan")

    def coverage(self) -> float:
        """Realized coverage over every observed completion."""
        return 1.0 - self.miscovered / max(self.observed, 1)

    def drift_alarmed(self) -> bool:
        """Windowed coverage/MAE alarm (full windows only, to avoid noisy
        warm-up trips)."""
        c = self.cfg
        if c.coverage_alarm > 0 and len(self._cov_win) == c.window:
            if self.rolling_coverage() < c.target_coverage - c.coverage_alarm:
                return True
        if (c.mae_alarm_mult > 0 and self._mae_baseline is not None
                and len(self._mae_win) == c.window):
            if self.rolling_mae() > c.mae_alarm_mult * self._mae_baseline:
                return True
        return False

    def maybe_refresh(self, now: float) -> bool:
        """Re-fit the head on the completion buffer when a scheduled refresh
        is due or a drift alarm fires, then hot-swap the weights into the
        base service. No-op for weight-less base predictors.

        Both residual windows are cleared on a refresh: the pending
        residuals were scored by the OLD weights, so keeping them would let
        a just-handled alarm re-fire before the refreshed head produced a
        single observation. Since :meth:`drift_alarmed` only trips on full
        windows, the clear doubles as the alarm cooldown — measured in
        completions, the unit the windows are in."""
        c = self.cfg
        if not hasattr(self.base, "swap_weights"):
            return False
        if len(self._buf_len) < c.refresh_min_samples:
            return False
        due = (c.refresh_every > 0
               and now - self._last_refresh >= c.refresh_every)
        alarmed = self.drift_alarmed()
        if not (due or alarmed):
            return False
        new = refit_head(self.base.predictor, np.stack(self._buf_phi),
                         np.asarray(self._buf_len), epochs=c.refresh_epochs,
                         seed=c.refresh_seed + self.refreshes)
        self.base.swap_weights(new)
        self._last_refresh = float(now)
        self.refreshes += 1
        if self.tracer is not None:
            self.tracer.emit(now, -1, -1, "refresh", version=self.refreshes,
                             alarmed=int(alarmed), buffer=len(self._buf_len))
        self._cov_win.clear()
        self._mae_win.clear()
        self._mae_baseline = None
        return True

    def row(self) -> dict:
        """Adaptation summary for bench tables."""
        return dict(q_eff=self.q_eff, observed=self.observed,
                    coverage=self.coverage(),
                    rolling_coverage=self.rolling_coverage(),
                    rolling_mae=self.rolling_mae(),
                    refreshes=self.refreshes, buffer=len(self._buf_len))


@dataclass(frozen=True)
class AdmissionController:
    """SLO-aware admission at the cluster enqueue seam: reject early what
    would time out late.

    At dispatch the routed replica's finish time is estimated as
    ``now + slack × (predicted backlog / service rate + prefill ticks +
    ceil(q-reservation / speed))`` — the calibrated reservation is the
    pessimistic work estimate, so admission inherits the conformal
    controller's coverage guarantees. Requests whose estimate misses their
    deadline never enter the queue (``ClusterStats.rejected``, distinct from
    ``timed_out``); deadline-less requests are always admitted.

    ``slack`` scales the whole estimate: < 1 admits optimistically, > 1
    hedges. The decision reads only dispatch-tick engine state, so it is
    identical between the reference and vectorized decode paths.
    """

    slack: float = 1.0
    # optional telemetry sink — excluded from equality/hash so controllers
    # with and without tracing still compare equal on their policy knob
    tracer: object = dataclasses.field(default=None, repr=False, compare=False)

    def __post_init__(self):
        if self.slack <= 0:
            raise ValueError("slack must be positive")

    def admit(self, req: Request, engine, spec, now: float) -> bool:
        if req.deadline is None:
            if self.tracer is not None:
                self.tracer.emit(now, getattr(engine, "replica_id", -1),
                                 req.rid, "admission", ok=1, eta=float(now),
                                 deadline=-1.0)
            return True
        work = float(req.reserve_len) if req.reserve_len is not None \
            else quantile_remaining(req)
        # note: the engine grants the reservation page-rounded
        # (spec.page_size), but that slack is memory, not decode work — the
        # service-time estimate stays in raw tokens, so admission does not
        # over-reject short requests as pages grow
        decode = float(np.ceil(work / spec.speed))
        if spec.step_token_budget is not None:
            # chunked-prefill cost model: the prompt is consumed in chunks of
            # prefill_chunk_tokens (whole budget when atomic) drawn from the
            # per-step token budget, so prefill latency is ceil(prompt/chunk)
            ce = min(spec.prefill_chunk_tokens or spec.step_token_budget,
                     spec.step_token_budget)
            prefill = float(-(-int(req.prompt_len) // ce))
        else:
            pts = spec.prefill_tokens_per_step
            prefill = float(-(-int(req.prompt_len) // pts)) if pts > 0 else 0.0
        wait = engine.predicted_backlog() / spec.service_rate
        eta = now + self.slack * (wait + prefill + decode)
        ok = eta <= float(req.deadline)
        if self.tracer is not None:
            self.tracer.emit(now, getattr(engine, "replica_id", -1), req.rid,
                             "admission", ok=int(ok), eta=float(eta),
                             deadline=float(req.deadline))
        return ok
