"""Multi-replica serving cluster: a router dispatching an open-loop trace to
N independent :class:`SimEngine` replicas stepped in lockstep.

Router policies:

* ``round_robin`` — rid-order rotation, load-blind (the baseline);
* ``jsq``         — join-shortest-queue by outstanding request count;
* ``least_kv``    — least outstanding reserved-KV (active reservations plus
  queued reservation needs): memory-pressure-aware but length-blind;
* ``psq``         — predicted-shortest-queue: joins the replica with the
  least *predicted remaining decode tokens* (active + queued). This is the
  router only a length predictor enables; with ``reserve="quantile"`` the
  same ProD-D distribution also sizes each request's KV reservation, giving
  the full prediction-aware serving stack.

All replicas share one global clock; dispatch happens at request arrival
(open loop — the router never sees realized lengths, only predictions).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

import numpy as np

from repro.serving.engine import SimEngine, _latency_stats
from repro.serving.request import Request
from repro.serving.scheduler import Policy, annotate_predictions

ROUTERS = ("round_robin", "jsq", "least_kv", "psq")


@dataclass
class ClusterStats:
    router: str
    policy: str
    n_replicas: int
    makespan: float
    mean_latency: float
    p50_latency: float
    p90_latency: float
    p99_latency: float
    mean_wait: float
    throughput: float              # completed tokens / step, cluster-wide
    kv_waste_ratio: float          # aggregate over replicas
    overflow_events: int
    completed: int
    preemptions: int = 0
    oom_evictions: int = 0
    dropped: int = 0
    balance: float = 1.0           # max/mean completed tokens per replica
    replica_rows: List[dict] = field(default_factory=list)

    def row(self) -> dict:
        d = self.__dict__.copy()
        d.pop("replica_rows")
        return d


class Cluster:
    """N-replica trace-driven cluster simulator."""

    def __init__(self, n_replicas: int, max_slots: int, kv_budget: int,
                 policy: Policy, router: str = "round_robin", predictor=None,
                 vectorized: bool = True):
        if router not in ROUTERS:
            raise ValueError(f"router {router!r} not in {ROUTERS}")
        self.n_replicas = n_replicas
        self.router = router
        self.policy = policy
        self.predictor = predictor
        self.engines = [
            SimEngine(max_slots, kv_budget, policy, predictor=None,
                      vectorized=vectorized)
            for _ in range(n_replicas)
        ]
        self._rr = 0

    # -- dispatch ------------------------------------------------------------

    def _route(self, req: Request) -> int:
        if self.router == "round_robin":
            i = self._rr
            self._rr = (self._rr + 1) % self.n_replicas
            return i
        if self.router == "jsq":
            loads = [e.outstanding_requests for e in self.engines]
        elif self.router == "least_kv":
            loads = [e.outstanding_kv for e in self.engines]
        else:  # psq: ProD predicted-remaining-token backlog
            loads = [e.predicted_backlog() for e in self.engines]
        return int(np.argmin(loads))

    # -- lockstep replay -----------------------------------------------------

    def run(self, requests: Sequence[Request],
            max_steps: int = 10_000_000) -> ClusterStats:
        reqs = [Request(**{**r.__dict__}) for r in requests]
        annotate_predictions(reqs, self.predictor, self.policy)
        reqs.sort(key=lambda r: r.arrival)
        vectorized = all(e.vectorized for e in self.engines)
        for e in self.engines:
            e.reset()
        self._rr = 0
        t = 0.0
        ptr, n = 0, len(reqs)
        while True:
            while ptr < n and reqs[ptr].arrival <= t:
                r = reqs[ptr]
                i = self._route(r)
                r.replica = i
                self.engines[i].submit([r])
                ptr += 1
            if ptr >= n and all(e.idle for e in self.engines):
                break
            if t >= max_steps:
                break
            if vectorized:
                # lockstep event leap: jump all replicas over the span in
                # which no replica can admit/preempt/grow/complete and no
                # trace arrival needs dispatching
                ks = [e.ticks_to_event() for e in self.engines]
                k = min(ks)
                if ptr < n:
                    # dispatch happens at loop start (arrival <= t), i.e. one
                    # tick earlier than an engine-internal arrival would fire
                    k = min(k, max(1.0, np.ceil(reqs[ptr].arrival - t)))
                q = int(min(k - 1, max(max_steps - t - 1, 0)))
                if q > 0:
                    for e in self.engines:
                        e.leap(q)
                    t += float(q)
                # replicas whose own next event is still ahead take the tick
                # as a 1-step leap (identical arithmetic, skips admit/decode
                # bookkeeping); only event replicas run the full step
                for e, ke in zip(self.engines, ks):
                    if ke - q > 1.0:
                        e.leap(1)
                    else:
                        e.step()
            else:
                for e in self.engines:
                    e.step()
            t += 1.0
        return self._stats(t)

    def _stats(self, t: float) -> ClusterStats:
        done = [r for e in self.engines for r in e.done]
        toks = sum(r.true_len for r in done)
        reserved_steps = sum(e.kv.total_reserved_steps for e in self.engines)
        used_steps = sum(e.kv.total_used_steps for e in self.engines)
        waste = (1.0 - used_steps / reserved_steps) if reserved_steps else 0.0
        per_replica_toks = np.array(
            [sum(r.true_len for r in e.done) for e in self.engines], float)
        mean_toks = max(float(per_replica_toks.mean()), 1e-9)
        return ClusterStats(
            router=self.router,
            policy=f"{self.policy.order}+{self.policy.reserve}",
            n_replicas=self.n_replicas,
            makespan=t,
            throughput=toks / max(t, 1.0),
            kv_waste_ratio=waste,
            overflow_events=sum(e.kv.overflow_events for e in self.engines),
            completed=len(done),
            preemptions=sum(e.preemptions for e in self.engines),
            oom_evictions=sum(e.oom_evictions for e in self.engines),
            dropped=sum(e.dropped for e in self.engines),
            balance=float(per_replica_toks.max()) / mean_toks,
            replica_rows=[e.stats().row() for e in self.engines],
            **_latency_stats(done),
        )
