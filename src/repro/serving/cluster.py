"""Multi-replica serving cluster: a router dispatching an open-loop trace to
N (possibly heterogeneous) :class:`SimEngine` replicas stepped in lockstep.

Each replica is described by a :class:`~repro.serving.engine.ReplicaSpec`
(slots, KV budget, decode speed, prefill rate), so the cluster can model a
mixed fleet — e.g. two fast large-memory accelerators next to two slow small
ones. Router load signals are speed-aware: they normalize by each replica's
service rate / budget, so a twice-as-fast replica looks half as loaded at
equal backlog (for a homogeneous fleet this reduces exactly to the unscaled
signals).

Router policies:

* ``round_robin`` — rid-order rotation, load-blind (the baseline);
* ``jsq``         — join-shortest-queue: outstanding requests per unit of
  service rate (slots × speed);
* ``least_kv``    — least outstanding reserved-KV *fraction* (active
  reservations plus queued reservation needs, over the replica's budget):
  memory-pressure-aware but length-blind;
* ``psq``         — predicted-shortest-queue: joins the replica with the
  least *predicted remaining decode tokens* per unit of service rate
  (active + queued). This is the router only a length predictor enables;
  with ``reserve="quantile"`` the same ProD-D distribution also sizes each
  request's KV reservation, giving the full prediction-aware serving stack.
* ``prefix_affine`` — prefix-affinity routing for shared-context traffic:
  a request carrying a ``prefix_id`` joins the least-loaded replica already
  holding that prefix's shared KV pages (so it hits the cache instead of
  re-prefilling and re-reserving the common context), *unless* every holder
  is overloaded — more than ``prefix_imbalance`` requests-worth of load
  above the lightest replica — in which case it falls back to jsq. A prefix
  resident everywhere (a hot system prompt) routes exactly like jsq;
  prefix-less requests route plain jsq too.

Work stealing: with ``rebalance_every=k`` the cluster pauses every k steps
and migrates *queued* (never active — their KV lives on the donor) requests
from the most- to the least-loaded replica under the router's own load
metric, until their queue lengths meet in the middle. ``steal="quantile"``
is the ProD-aware variant: it steals the requests with the largest
predicted-quantile remaining work, moving the most token-load per migration;
``steal="tail"`` takes the entries the donor would serve last. A preempted
request holding kept pages (``Policy.preempt_mode="keep"``) migrates them
with it — the donor releases, the thief re-reserves (page handoff), and the
``steal_cost`` delay scales with the pages moved.

All replicas share one global clock; dispatch happens at request arrival
(open loop — the router never sees realized lengths, only predictions).

Closed-loop adaptation: passing an
:class:`~repro.serving.adaptation.OnlineAdapter` as the ``predictor``
switches :meth:`Cluster.run` into its feedback mode — requests are annotated
at dispatch time with the adapter's current calibration/weights, and
observed completions flow back at fixed checkpoints. An optional
``admission`` controller can reject SLO-infeasible requests at the enqueue
seam, and ``steal_cost`` charges a migration delay on stolen work.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

import numpy as np

from repro.serving.engine import (ReplicaSpec, SimEngine, _goodput,
                                  _latency_stats, _ttft_stats)
from repro.serving.request import Request
from repro.serving.scheduler import Policy, annotate_predictions

ROUTERS = ("round_robin", "jsq", "least_kv", "psq", "prefix_affine")
STEAL_MODES = ("tail", "quantile")


@dataclass
class ClusterStats:
    router: str
    policy: str
    n_replicas: int
    makespan: float
    mean_latency: float
    p50_latency: float
    p90_latency: float
    p99_latency: float
    mean_wait: float
    throughput: float              # completed tokens / step, cluster-wide
    kv_waste_ratio: float          # aggregate over replicas
    overflow_events: int
    completed: int
    preemptions: int = 0
    oom_evictions: int = 0
    dropped: int = 0
    timed_out: int = 0             # queue entries expired before starting
    slo_violations: int = 0        # completed past their deadline
    goodput: float = 0.0           # within-SLO completed tokens / step
    stolen: int = 0                # queued requests migrated by rebalancing
    steal_delay: int = 0           # total migration-delay ticks charged
    steal_pages: int = 0           # total KV pages moved by migrations
    rejected: int = 0              # admission-controlled away at enqueue
    refreshes: int = 0             # predictor weight swaps during the run
    balance: float = 1.0           # max/mean completed tokens per replica
    # paged-KV accounting, aggregated over replicas (see ServeStats)
    occupancy: float = 0.0         # mean reserved fraction of the fleet pool
    frag_ratio: float = 0.0        # page-rounding slack / reserved integral
    held_peak: int = 0             # Σ per-replica peak held tokens
    held_steps: float = 0.0        # Σ token-steps held while preempted-queued
    held_releases: int = 0         # Σ held pages dropped to break stalls
    recompute_ticks: int = 0       # prefill ticks re-paid for preempted work
    # prefix sharing, aggregated over replicas (inert without sharing)
    kv_amplification: float = 1.0  # Σ logical / Σ physical reserved steps
    prefix_hits: int = 0           # admissions that reused shared pages
    cow_copies: int = 0            # divergence-boundary pages privatized
    prefix_evictions: int = 0      # cached prefixes reclaimed under pressure
    prefill_ticks: int = 0         # prefill ticks actually paid
    prefill_saved_ticks: int = 0   # prefill ticks erased by prefix hits
    shared_peak: int = 0           # Σ per-replica peak shared tokens
    # posterior refinement, aggregated over replicas (0 with refine off)
    refine_events: int = 0
    refine_shrinks: int = 0
    refine_grows: int = 0
    # time-to-first-token percentiles over all completed requests (inf when
    # none emitted; see ServeStats)
    mean_ttft: float = float("inf")
    p50_ttft: float = float("inf")
    p90_ttft: float = float("inf")
    p99_ttft: float = float("inf")
    replica_rows: List[dict] = field(default_factory=list)

    def row(self) -> dict:
        d = self.__dict__.copy()
        # per-replica rows are exported separately (Tracer.sample_cluster /
        # replica gauge series), not as a flat scalar column
        d.pop("replica_rows")  # reprolint: disable=stats-exporter-surfacing
        return d


class Cluster:
    """N-replica trace-driven cluster simulator over per-replica specs.

    Parameters
    ----------
    specs : one :class:`~repro.serving.engine.ReplicaSpec` per replica
        (slots, KV budget, decode speed, prefill rate) — the fleet.
    policy : scheduling :class:`~repro.serving.scheduler.Policy` every
        replica runs (queue ordering × KV reservation sizing).
    router : dispatch policy, one of :data:`ROUTERS` (module docstring).
    predictor : the length predictor behind the prediction-aware paths
        (psq routing, quantile reservation, laxity ordering, quantile
        stealing). Interchangeable implementations of the same seam:
        :class:`~repro.serving.arrivals.LatentOracle` (analytic trace proxy),
        :class:`~repro.serving.predictor.PredictorService` (trained ProD-D
        head, batched jitted dispatch-time inference), and
        :class:`~repro.serving.predictor.PerfectOracle` (realized lengths —
        the upper bound). ``None`` keeps pre-annotated trace predictions.
    vectorized : use the NumPy fast path + event leap (bit-identical to the
        per-slot reference; ``False`` forces the reference loop).
    rebalance_every : steal queued work every k steps (0 disables).
    steal : victim selection, one of :data:`STEAL_MODES`.
    steal_cost : migration delay in ticks *per KV page moved* (prompt
        re-transfer, plus any kept pages a preempted holder carries): a
        migrated entry becomes runnable on the thief only
        ``steal_cost × pages_moved`` ticks after the rebalance (0 keeps the
        free-migration model). Total charged delay and pages appear in
        ``ClusterStats.steal_delay`` / ``steal_pages``.
    admission : optional SLO-aware admission controller (an object with
        ``admit(request, engine, spec, now) -> bool``, e.g.
        :class:`~repro.serving.adaptation.AdmissionController`): requests it
        declines at dispatch are counted as ``rejected`` and never enqueued.
    prefix_imbalance : ``prefix_affine`` only — how much extra load (in
        requests, normalized by the holding replica's service rate) a
        prefix-holding replica may carry over the lightest one before
        affinity yields to jsq. 0 = pure load balancing, large = sticky
        sessions.
    refiner : optional :class:`~repro.core.online.PosteriorRefiner` over
        the predictor's bin edges, handed to every replica engine. Required
        when ``policy.refine_every > 0``; ignored otherwise.

    A ``predictor`` that also exposes ``observe`` (an
    :class:`~repro.serving.adaptation.OnlineAdapter`) switches :meth:`run`
    into its closed loop: requests are annotated at dispatch time instead
    of up front, and observed completions are fed back at fixed
    ``adapter.cfg.every``-tick checkpoints (in a canonical order, so both
    decode paths see the identical feedback stream).
    """

    def __init__(self, specs: Sequence[ReplicaSpec], policy: Policy,
                 router: str = "round_robin", predictor=None,
                 vectorized: bool = True, rebalance_every: int = 0,
                 steal: str = "tail", steal_cost: int = 0, admission=None,
                 prefix_imbalance: float = 8.0, refiner=None, tracer=None):
        if router not in ROUTERS:
            raise ValueError(f"router {router!r} not in {ROUTERS}")
        if steal not in STEAL_MODES:
            raise ValueError(f"steal {steal!r} not in {STEAL_MODES}")
        if steal_cost < 0:
            raise ValueError("steal_cost must be >= 0")
        if prefix_imbalance < 0:
            raise ValueError("prefix_imbalance must be >= 0")
        specs = tuple(specs)
        if not specs:
            raise ValueError("need at least one ReplicaSpec")
        self.specs = specs
        self.n_replicas = len(specs)
        self.router = router
        self.policy = policy
        self.predictor = predictor
        self.rebalance_every = int(rebalance_every)
        self.steal = steal
        self.steal_cost = int(steal_cost)
        self.admission = admission
        self.prefix_imbalance = float(prefix_imbalance)
        self._prefix_home: dict = {}    # prefix_id -> last replica routed to
        self.stolen = 0
        self.steal_delay = 0
        self.steal_pages = 0
        self.rejected_requests: List[Request] = []
        self.refiner = refiner
        # optional telemetry, shared with every replica engine: the cluster
        # emits dispatch-level events (arrival/routed/rejected/stolen) and
        # fleet gauge rows; engines emit slot-level events + per-replica rows
        self.tracer = tracer
        self.engines = [
            SimEngine(policy=policy, predictor=None, vectorized=vectorized,
                      spec=spec, refiner=refiner, tracer=tracer)
            for spec in specs
        ]
        for i, e in enumerate(self.engines):
            e.replica_id = i
        self._rr = 0
        self._done_seen = [0] * self.n_replicas

    @classmethod
    def uniform(cls, n_replicas: int, max_slots: int, kv_budget: int,
                policy: Policy, page_size: int = 1,
                share_prefixes: bool = False, **kw) -> "Cluster":
        """Homogeneous fleet — the pre-heterogeneity constructor shape."""
        spec = ReplicaSpec(max_slots=max_slots, kv_budget=kv_budget,
                           page_size=page_size, share_prefixes=share_prefixes)
        return cls([spec] * n_replicas, policy, **kw)

    # -- dispatch ------------------------------------------------------------

    def _loads(self) -> List[float]:
        """Per-replica load under the router's own metric, normalized by
        replica capacity so heterogeneous fleets compare fairly."""
        if self.router == "least_kv":
            return [e.outstanding_kv / s.kv_budget
                    for e, s in zip(self.engines, self.specs)]
        if self.router == "psq":
            return [e.predicted_backlog() / s.service_rate
                    for e, s in zip(self.engines, self.specs)]
        # jsq — prefix_affine's base metric, and the rebalance metric for
        # round_robin
        return [e.outstanding_requests / s.service_rate
                for e, s in zip(self.engines, self.specs)]

    def _route(self, req: Request) -> int:
        if self.router == "round_robin":
            i = self._rr
            self._rr = (self._rr + 1) % self.n_replicas
            return i
        loads = self._loads()
        # capacity-aware: never choose a replica whose whole KV pool cannot
        # hold the request when one that can exists (on a no-fit fleet the
        # engine drops the request as unservable)
        need = int(req.prompt_len + req.reserve_len)
        fits = [i for i, s in enumerate(self.specs) if need <= s.kv_budget]
        pool = fits if fits and len(fits) < self.n_replicas \
            else range(self.n_replicas)
        best = min(pool, key=lambda i: loads[i])
        if self.router != "prefix_affine" or req.prefix_id is None:
            return best
        # affinity: among the replicas whose pool still holds this prefix's
        # shared pages, join the least loaded. For a prefix every replica has
        # warmed (a hot system prompt) this degenerates to exactly jsq; a
        # session context resident on one replica pulls its turns back there.
        # A prefix queued but not yet admitted has no resident pages anywhere,
        # so the replica the session was last routed to stands in as holder.
        pid = req.prefix_id
        holders = [i for i in pool if self.engines[i].kv.has_prefix(pid)]
        if not holders:
            home = self._prefix_home.get(pid)
            if home is not None and home in pool:
                holders = [home]
        if holders:
            near = min(holders, key=lambda i: loads[i])
            if (loads[near] <= loads[best]
                    + self.prefix_imbalance / self.specs[near].service_rate):
                self._prefix_home[pid] = near
                return near
        self._prefix_home[pid] = best
        return best

    # -- work stealing -------------------------------------------------------

    def _rebalance(self):
        """Migrate queued requests from the most- to the least-loaded replica
        (router load metric). The steal size equalizes *service-rate-
        normalized* queue lengths — (qd−k)/rate_d == (qt+k)/rate_t, which
        reduces to (qd−qt)/2 for equal rates — so a fast replica standing
        next to a slow one with the same raw queue length still steals.
        Only requests that fit the thief's KV pool move, and active slots
        never move — their KV pages live on the donor."""
        loads = self._loads()
        donor = int(np.argmax(loads))
        thief = int(np.argmin(loads))
        if donor == thief:
            return
        d_eng, t_eng = self.engines[donor], self.engines[thief]
        rd = self.specs[donor].service_rate
        rt = self.specs[thief].service_rate
        # queue length counts in-transit migrations (the thief's future heap
        # under steal_cost > 0) — otherwise back-to-back rebalances see the
        # thief as empty and keep over-stealing to it
        qd = len(d_eng._ready) + len(d_eng._future)
        qt = len(t_eng._ready) + len(t_eng._future)
        k = int((qd * rt - qt * rd) / (rd + rt))
        if k <= 0:
            return
        # the fit filter must round needs to the THIEF's page granularity:
        # its page-rounded grant is what has to fit its pool, not raw tokens
        moved = d_eng.steal_queued(k, mode=self.steal,
                                   fit=self.specs[thief].kv_budget,
                                   fit_page_size=self.specs[thief].page_size)
        for r in moved:
            r.replica = thief
            # pages moved: a keep-mode holder carries its kept prompt+progress
            # KV pages; a plain queued request — or a holder whose handoff
            # the thief's pool refuses (pages dropped, recompute there) —
            # only re-transfers its prompt
            held_pages = r.held // d_eng.kv.page_size if r.held else 0
            d_eng.export_held(r)
            pages = held_pages if t_eng.adopt_held(r) \
                else d_eng.kv.pages_for(r.prompt_len)
            self.steal_pages += pages
            delay = self.steal_cost * pages
            if self.steal_cost > 0:
                # migration isn't free: the stolen entry only becomes
                # runnable on the thief after a delay proportional to the
                # KV pages it moves (steal_cost ticks per page)
                t_eng.submit([r], after=t_eng.t + delay)
                self.steal_delay += delay
            else:
                t_eng.submit([r])
            if self.tracer is not None:
                self.tracer.emit(t_eng.t, thief, r.rid, "stolen", frm=donor,
                                 pages=int(pages), delay=int(delay))
        self.stolen += len(moved)

    # -- adaptation feedback (closed loop) -----------------------------------

    def _harvest_done(self) -> List[Request]:
        """Newly finished requests since the last harvest, in a canonical
        global order — (finish tick, replica, completion order) — that is
        independent of how often the harvest runs, so the adapter's feedback
        stream is bit-identical between the reference (every tick) and
        event-leap (sparse iterations) paths."""
        fresh = []
        for i, e in enumerate(self.engines):
            done = e.done
            for j in range(self._done_seen[i], len(done)):
                fresh.append((float(done[j].t_finish), i, j, done[j]))
            self._done_seen[i] = len(done)
        fresh.sort(key=lambda x: x[:3])
        return [x[3] for x in fresh]

    # -- lockstep replay -----------------------------------------------------

    def run(self, requests: Sequence[Request],
            max_steps: int = 10_000_000) -> ClusterStats:
        reqs = [r.fresh_copy() for r in requests]
        adapter = self.predictor if hasattr(self.predictor, "observe") \
            else None
        if adapter is None:
            annotate_predictions(reqs, self.predictor, self.policy)
        else:
            adapter.reset()
        reqs.sort(key=lambda r: r.arrival)
        vectorized = all(e.vectorized for e in self.engines)
        for e in self.engines:
            e.reset()
        self._rr = 0
        self._prefix_home = {}
        self.stolen = 0
        self.steal_delay = 0
        self.steal_pages = 0
        self.rejected_requests = []
        self._done_seen = [0] * self.n_replicas
        t = 0.0     # advances in unit ticks (plus integer leaps) from 0.0
        next_reb = self.rebalance_every if self.rebalance_every > 0 else None
        next_adapt = float(adapter.cfg.every) if adapter is not None else None
        tracer = self.tracer
        next_obs = float(tracer.sample_every) \
            if tracer is not None and tracer.sample_every else None
        ptr, n = 0, len(reqs)
        while True:
            batch = []
            while ptr < n and reqs[ptr].arrival <= t:
                batch.append(reqs[ptr])
                ptr += 1
            if batch:
                if adapter is not None:
                    # closed loop: annotate at dispatch time with the
                    # adapter's CURRENT calibration and weights
                    adapter.annotate(batch, self.policy)
                for r in batch:
                    if tracer is not None:
                        tracer.emit(r.arrival, -1, r.rid, "arrival")
                    i = self._route(r)
                    if (self.admission is not None
                            and not self.admission.admit(
                                r, self.engines[i], self.specs[i], t)):
                        if self.router == "round_robin":
                            # a rejected request never enqueues, so it must
                            # not burn the rotation slot either
                            self._rr = (self._rr - 1) % self.n_replicas
                        self.rejected_requests.append(r)
                        if tracer is not None:
                            tracer.emit(t, i, r.rid, "rejected")
                        continue
                    r.replica = i
                    if tracer is not None:
                        tracer.emit(t, i, r.rid, "routed", to=i)
                    self.engines[i].submit([r])
            if next_adapt is not None and t >= next_adapt:
                adapter.observe(self._harvest_done())
                adapter.maybe_refresh(t)
                next_adapt += adapter.cfg.every
            if next_reb is not None and t >= next_reb:
                self._rebalance()
                next_reb += self.rebalance_every
            if next_obs is not None and t >= next_obs:
                # fleet-level gauge row (replica = -1) each sample tick; the
                # per-engine rows fire inside each engine's own step()
                tracer.sample_cluster(self, t)
                next_obs += tracer.sample_every
            if ptr >= n and all(e.idle for e in self.engines):
                break
            if t >= max_steps:
                break
            if vectorized:
                # lockstep event leap: jump all replicas over the span in
                # which no replica can admit/preempt/grow/complete, no trace
                # arrival needs dispatching, and no rebalance or adaptation
                # tick falls
                ks = [e.ticks_to_event() for e in self.engines]
                k = min(ks)
                if ptr < n:
                    # dispatch happens at loop start (arrival <= t), i.e. one
                    # tick earlier than an engine-internal arrival would fire
                    k = min(k, max(1.0, np.ceil(reqs[ptr].arrival - t)))
                if next_reb is not None:
                    k = min(k, max(1.0, float(next_reb) - t))
                if next_adapt is not None:
                    k = min(k, max(1.0, float(next_adapt) - t))
                if next_obs is not None:
                    k = min(k, max(1.0, float(next_obs) - t))
                q = int(min(k - 1, max(max_steps - t - 1, 0)))
                if q > 0:
                    for e in self.engines:
                        e.leap(q)
                    t += float(q)
                # replicas whose own next event is still ahead take the tick
                # as a 1-step leap (identical arithmetic, skips admit/decode
                # bookkeeping); only event replicas run the full step
                for e, ke in zip(self.engines, ks):
                    if ke - q > 1.0:
                        e.leap(1)
                    else:
                        e.step()
            else:
                for e in self.engines:
                    e.step()
            t += 1.0
        if adapter is not None:
            # final harvest: completions between the last checkpoint and the
            # end of the run still count toward coverage totals
            adapter.observe(self._harvest_done())
        return self._stats(t, adapter)

    def _stats(self, t: float, adapter=None) -> ClusterStats:
        done = [r for e in self.engines for r in e.done]
        toks = sum(r.true_len for r in done)
        reserved_steps = sum(e.kv.total_reserved_steps for e in self.engines)
        asked_steps = sum(e.kv.total_asked_steps for e in self.engines)
        used_steps = sum(e.kv.total_used_steps for e in self.engines)
        logical_steps = sum(e.kv.total_logical_steps for e in self.engines)
        waste = (1.0 - used_steps / reserved_steps) if reserved_steps else 0.0
        frag = (1.0 - asked_steps / reserved_steps) if reserved_steps else 0.0
        amp = (logical_steps / reserved_steps) if reserved_steps else 1.0
        capacity = sum(e.kv.capacity_tokens for e in self.engines)
        per_replica_toks = np.array(
            [sum(r.true_len for r in e.done) for e in self.engines], float)
        mean_toks = max(float(per_replica_toks.mean()), 1e-9)
        return ClusterStats(
            router=self.router,
            policy=f"{self.policy.order}+{self.policy.reserve}",
            n_replicas=self.n_replicas,
            makespan=t,
            throughput=toks / max(t, 1.0),
            kv_waste_ratio=waste,
            overflow_events=sum(e.kv.overflow_events for e in self.engines),
            completed=len(done),
            preemptions=sum(e.preemptions for e in self.engines),
            oom_evictions=sum(e.oom_evictions for e in self.engines),
            dropped=sum(e.dropped for e in self.engines),
            timed_out=sum(e.timed_out for e in self.engines),
            slo_violations=sum(e.slo_violations for e in self.engines),
            goodput=_goodput(done, t),
            stolen=self.stolen,
            steal_delay=self.steal_delay,
            steal_pages=self.steal_pages,
            rejected=len(self.rejected_requests),
            refreshes=adapter.refreshes if adapter is not None else 0,
            balance=float(per_replica_toks.max()) / mean_toks,
            occupancy=reserved_steps / (max(t, 1.0) * max(capacity, 1)),
            frag_ratio=frag,
            held_peak=sum(e._held_peak for e in self.engines),
            held_steps=sum(e._held_steps for e in self.engines),
            held_releases=sum(e.held_releases for e in self.engines),
            recompute_ticks=sum(e.recompute_ticks for e in self.engines),
            kv_amplification=amp,
            prefix_hits=sum(e.kv.prefix_hits for e in self.engines),
            cow_copies=sum(e.kv.cow_copies for e in self.engines),
            prefix_evictions=sum(e.kv.prefix_evictions
                                 for e in self.engines),
            prefill_ticks=sum(e.prefill_ticks for e in self.engines),
            prefill_saved_ticks=sum(e.prefill_saved_ticks
                                    for e in self.engines),
            shared_peak=sum(e.kv.shared_peak for e in self.engines),
            refine_events=sum(e.refine_events for e in self.engines),
            refine_shrinks=sum(e.refine_shrinks for e in self.engines),
            refine_grows=sum(e.refine_grows for e in self.engines),
            replica_rows=[e.stats().row() for e in self.engines],
            **_latency_stats(done),
            **_ttft_stats(done),
        )
