"""Pallas TPU chunked SSD scan (Mamba2's compute hot spot).

Grid ``(B, n_chunks)`` — chunks iterate fastest, carrying the (H, P, N)
inter-chunk state in VMEM scratch. Within a chunk everything is dense
matmul work (C·Bᵀ scores, decay-weighted combine, state outer-products) —
exactly the MXU-friendly reformulation that state-space duality buys.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
    *, chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)        # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, H)
    a = a_ref[0].astype(jnp.float32)        # (Q, H)
    Bm = b_ref[0].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)       # (Q, N)
    h = h_ref[...]                          # (H, P, N)

    cum = jnp.cumsum(a, axis=0)             # (Q, H)
    L = jnp.exp(cum[:, None, :] - cum[None, :, :])            # (Q, Q, H)
    Q = chunk
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri[:, :, None], L, 0.0)
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # (Q, Q)
    scores = CB[:, :, None] * L * dt[None, :, :]              # (Q, Q, H)
    y_intra = jnp.einsum("qkh,khp->qhp", scores, x)
    y_inter = jnp.einsum("qn,qh,hpn->qhp", Cm, jnp.exp(cum), h)

    cum_last = cum[-1:, :]                                    # (1, H)
    decay_to_end = jnp.exp(cum_last - cum) * dt               # (Q, H)
    state_new = jnp.einsum("kn,kh,khp->hpn", Bm, decay_to_end, x)
    h_ref[...] = jnp.exp(cum_last[0])[:, None, None] * h + state_new

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _fini():
        hout_ref[0] = h_ref[...]


def ssd_scan_pallas(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)
    a: jax.Array,    # (B, S, H) log-decay
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,S,H,P), h_final (B,H,P,N) fp32). S must be chunk-padded
    by the wrapper (ops.ssd_scan handles padding)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    y, h = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, Bm, Cm)
    return y, h
