"""Pallas TPU flash-decode: one query token vs. a long KV cache.

Grid ``(B, KV, n_kv_blocks)`` — each program attends the G query heads of one
GQA group to one KV block, accumulating the online softmax in VMEM scratch.
This is the serving-decode hot spot: arithmetic intensity ~1 (memory bound),
so the kernel's job is to stream K/V through VMEM exactly once.

Cache-validity lengths arrive via scalar prefetch (SMEM) so the mask is
computed from iota without materializing a (B, S) bool array.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,                  # SMEM (B,) int32 — scalar-prefetched lengths
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, block_kv: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)         # (G, hd)
    k = k_ref[0].astype(jnp.float32)            # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                    # (G, bkv)
    kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _fini():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,            # (B, H, hd)
    k: jax.Array,            # (B, Sc, KV, hd)
    v: jax.Array,            # (B, Sc, KV, hd)
    lengths: jax.Array,      # (B,) int32
    *,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    _, Sc, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_kv = min(block_kv, Sc)
    pad = (-Sc) % block_kv
    kh = jnp.moveaxis(k, 2, 1)                   # (B, KV, Sc, hd)
    vh = jnp.moveaxis(v, 2, 1)
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nkv = (Sc + pad) // block_kv
    qg = q.reshape(B, KV, G, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, h, ki, lens: (b * KV + h, ki, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, h, ki, lens: (b * KV + h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_kv=block_kv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg,
      kh.reshape(B * KV, Sc + pad, hd), vh.reshape(B * KV, Sc + pad, hd))
    return out.reshape(B, H, hd)
