"""Public jit'd wrappers for the Pallas kernels with impl dispatch.

``impl``:
* ``"auto"``      — Pallas on TPU backends, XLA/jnp oracle elsewhere (CPU CI).
* ``"pallas"``    — compiled Pallas (TPU).
* ``"interpret"`` — Pallas in interpret mode (kernel body executed in Python
                    on CPU; used by the correctness test sweeps).
* ``"xla"``       — the pure-jnp reference path.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.prod_head import prod_head_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_kv=128,
                    impl="auto"):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q, block_kv=block_kv,
        interpret=(impl == "interpret"),
    )


def decode_attention(q, k, v, lengths, *, block_kv=256, impl="auto"):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.decode_attention_ref(q, k, v, lengths)
    return decode_attention_pallas(
        q, k, v, lengths, block_kv=block_kv, interpret=(impl == "interpret")
    )


def ssd_scan(x, dt, a, Bm, Cm, *, chunk=128, impl="auto"):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.ssd_scan_ref(x, dt, a, Bm, Cm)
    S = x.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        # padded steps must not decay the carried state: a=0 and dt=0
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_scan_pallas(x, dt, a, Bm, Cm, chunk=chunk,
                           interpret=(impl == "interpret"))
    return y[:, :S], h


def prod_head(phi, w1, b1, w2, b2, edges, *, qs=None, block_b=128, impl="auto"):
    """Fused head. ``qs=None`` returns (probs, median); ``qs`` an array of
    CDF levels returns (probs, quants (B, Q)) — all levels in one call."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.prod_head_ref(phi, w1, b1, w2, b2, edges, qs=qs)
    return prod_head_pallas(phi, w1, b1, w2, b2, edges, qs=qs, block_b=block_b,
                            interpret=(impl == "interpret"))
