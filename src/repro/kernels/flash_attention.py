"""Pallas TPU flash attention (prefill/train hot spot).

Grid ``(B*H, n_q_blocks, n_kv_blocks)`` — KV fastest, so the VMEM scratch
(m, l, acc) accumulates the online softmax across KV blocks for one Q tile.
GQA is handled in the K/V index maps (query head h reads kv head h // G).
BlockSpec tiles are MXU-aligned on (block_q, head_dim); masking (causal /
sliding-window / KV-length) is computed from iota inside the kernel, so no
(S, S) mask ever exists.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int, kv_len: int,
    block_q: int, block_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0].astype(jnp.float32)            # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)            # (bkv, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (bq, bkv)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                         # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _fini():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Skv, KV, hd)
    v: jax.Array,            # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Skv, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Skv, hd)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kh = jnp.pad(kh, ((0, 0), (0, pad_kv), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_kv), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nkv = (Skv + pad_kv) // block_kv

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KV + h // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            kv_len=Skv, block_q=block_q, block_kv=block_kv,
        ),
        grid=(B * H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), kv_index),
            pl.BlockSpec((1, block_kv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :Sq].reshape(B, H, Sq, hd)
    return jnp.moveaxis(out, 1, 2)
