"""Pallas TPU fused ProD predictor head (the paper's inference-path addition).

One kernel fuses: 2-layer MLP (d -> hidden -> K bins) + softmax + the
quantile-of-predictive-distribution decode (CDF crossing with in-bin linear
interpolation, §2.4 — the median is the q=0.5 special case). Runs on the
served model's last hidden state during prefill — fusing it keeps the paper's
"no additional inference cost" claim honest: one VMEM-resident matmul pair
per request, no HBM round-trips for intermediates. The serving-layer
:class:`~repro.serving.predictor.PredictorService` asks for several quantiles
(median for routing, q0.9 for laxity, the policy quantile for KV reservation)
in the same fused call.

Grid ``(n_batch_blocks,)`` with full weight panels resident in VMEM
(d ≤ 7168, hidden = 512, K ≤ 64 → ≤ ~8 MB in bf16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prod_head_kernel(phi_ref, w1_ref, b1_ref, w2_ref, b2_ref, edges_ref,
                      qs_ref, probs_ref, quant_ref):
    phi = phi_ref[...].astype(jnp.float32)            # (bb, d)
    h = jnp.maximum(
        jax.lax.dot_general(phi, w1_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b1_ref[...].astype(jnp.float32)[None, :], 0.0
    )
    logits = jax.lax.dot_general(
        h, w2_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + b2_ref[...].astype(jnp.float32)[None, :]       # (bb, K)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    probs_ref[...] = probs

    cdf = jnp.cumsum(probs, axis=-1)                   # (bb, K)
    K = probs.shape[-1]
    qs = qs_ref[...].astype(jnp.float32)               # (Q,)
    crossed = cdf[:, None, :] >= qs[None, :, None]     # (bb, Q, K)
    idx = jax.lax.broadcasted_iota(jnp.int32, crossed.shape, 2)
    k_star = jnp.min(jnp.where(crossed, idx, K - 1), axis=-1)      # (bb, Q)
    onehot = (idx == k_star[..., None]).astype(jnp.float32)
    p_k = jnp.sum(probs[:, None, :] * onehot, axis=-1)             # (bb, Q)
    cdf_k = jnp.sum(cdf[:, None, :] * onehot, axis=-1)
    cdf_prev = cdf_k - p_k
    t = jnp.clip((qs[None, :] - cdf_prev) / jnp.maximum(p_k, 1e-12), 0.0, 1.0)
    edges = edges_ref[...].astype(jnp.float32)          # (K+1,)
    left = jnp.sum(edges[None, None, :K] * onehot, axis=-1)
    right = jnp.sum(edges[None, None, 1 : K + 1] * onehot, axis=-1)
    quant_ref[...] = left + t * (right - left)


def prod_head_pallas(
    phi: jax.Array,       # (B, d)
    w1: jax.Array,        # (d, hidden)
    b1: jax.Array,
    w2: jax.Array,        # (hidden, K)
    b2: jax.Array,
    edges: jax.Array,     # (K+1,)
    *,
    qs: jax.Array = None,  # (Q,) CDF levels; None -> median only
    block_b: int = 128,
    interpret: bool = False,
):
    """Fused MLP + softmax + interpolated CDF-crossing decode.

    Returns ``(probs (B, K) fp32, median (B,) fp32)`` when ``qs`` is None
    (the original single-quantile shape), else ``(probs, quants (B, Q))``
    with one column per requested CDF level."""
    single = qs is None
    qs = jnp.array([0.5], jnp.float32) if single else jnp.asarray(qs, jnp.float32)
    Q = qs.shape[0]
    B, d = phi.shape
    hidden = w1.shape[1]
    K = w2.shape[1]
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        phi = jnp.pad(phi, ((0, pad), (0, 0)))
    nb = (B + pad) // block_b

    probs, quants = pl.pallas_call(
        _prod_head_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((hidden, K), lambda i: (0, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K + 1,), lambda i: (0,)),
            pl.BlockSpec((Q,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, K), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Q), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B + pad, K), jnp.float32),
            jax.ShapeDtypeStruct((B + pad, Q), jnp.float32),
        ],
        interpret=interpret,
    )(phi, w1, b1, w2, b2, edges, qs)
    if single:
        return probs[:B], quants[:B, 0]
    return probs[:B], quants[:B]
