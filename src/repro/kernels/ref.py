"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Tests sweep shapes/dtypes and ``assert_allclose`` kernel-vs-oracle; the model
code paths also use these (via ``ops``' ``xla`` impl) on non-TPU backends.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Skv, KV, hd)
    v: jax.Array,            # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,            # (B, H, hd)
    k: jax.Array,            # (B, Sc, KV, hd)
    v: jax.Array,            # (B, Sc, KV, hd)
    lengths: jax.Array,      # (B,) int32 — valid cache prefix
) -> jax.Array:
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) fp32
    a: jax.Array,    # (B, S, H) fp32 log-decay
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence. Returns (y (B,S,H,P), h (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, t):
        xt, dtt, at, Bt, Ct = t
        h = jnp.exp(at)[:, :, None, None] * h + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, Bt
        )
        return h, jnp.einsum("bhpn,bn->bhp", h, Ct)

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(a, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Cm, 1, 0).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def prod_head_ref(
    phi: jax.Array,       # (B, d) — served LLM last hidden state
    w1: jax.Array,        # (d, hidden)
    b1: jax.Array,        # (hidden,)
    w2: jax.Array,        # (hidden, K)
    b2: jax.Array,        # (K,)
    edges: jax.Array,     # (K+1,) bin edges
    qs: Optional[jax.Array] = None,   # (Q,) CDF levels; None -> median only
) -> Tuple[jax.Array, jax.Array]:
    """ProD predictor head (paper §2.4): 2-layer MLP -> softmax over K bins
    -> CDF-crossing quantile decode with in-bin linear interpolation.

    Returns (probs (B, K) fp32, median_estimate (B,) fp32) when ``qs`` is
    None, else (probs, quants (B, Q) fp32) — one column per CDF level.
    """
    with jax.named_scope("fusedkernel_prod_head"):
        return _prod_head_body(phi, w1, b1, w2, b2, edges, qs)


def _prod_head_body(phi, w1, b1, w2, b2, edges, qs=None):
    single = qs is None
    qs = jnp.array([0.5], jnp.float32) if single else jnp.asarray(qs, jnp.float32)
    h = jax.nn.relu(phi.astype(jnp.float32) @ w1.astype(jnp.float32) + b1.astype(jnp.float32))
    logits = h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    cdf = jnp.cumsum(probs, axis=-1)
    K = probs.shape[-1]
    crossed = cdf[:, None, :] >= qs[None, :, None]                # (B, Q, K)
    # first crossing, clamped to the last bin when float32 rounding keeps the
    # CDF below q (q→1) — same rule as the Pallas kernel, so impls agree
    k_star = jnp.min(jnp.where(crossed, jnp.arange(K)[None, None, :], K - 1),
                     axis=-1)
    cdf_prev = jnp.where(
        k_star > 0,
        jnp.take_along_axis(cdf[:, None, :].repeat(qs.shape[0], 1),
                            jnp.maximum(k_star - 1, 0)[..., None],
                            axis=-1)[..., 0], 0.0)
    p_k = jnp.take_along_axis(probs[:, None, :].repeat(qs.shape[0], 1),
                              k_star[..., None], axis=-1)[..., 0]
    t = jnp.clip((qs[None, :] - cdf_prev) / jnp.maximum(p_k, 1e-12), 0.0, 1.0)
    left = edges[k_star]
    right = edges[k_star + 1]
    quants = left + t * (right - left)
    if single:
        return probs, quants[:, 0]
    return probs, quants
