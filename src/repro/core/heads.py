"""The shared ProD predictor head (paper §2.4).

A 2-layer MLP: φ(x) ∈ R^d → 512 (ReLU) → K bin logits → softmax. Both ProD-M
and ProD-D use this exact head; the *only* difference is the training target.
The fused Pallas version lives in ``repro.kernels.prod_head``; this module is
the trainable jnp twin (identical math — asserted by the kernel tests).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.bins import decode as decode_probs
from repro.kernels import ops


def head_init(key: jax.Array, d: int, hidden: int, n_bins: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, hidden)) * (1.0 / jnp.sqrt(d)),
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, n_bins)) * (1.0 / jnp.sqrt(hidden)),
        "b2": jnp.zeros(n_bins),
    }


def head_logits(params: Dict[str, jax.Array], phi: jax.Array) -> jax.Array:
    h = jax.nn.relu(phi.astype(jnp.float32) @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def head_probs(params: Dict[str, jax.Array], phi: jax.Array) -> jax.Array:
    return jax.nn.softmax(head_logits(params, phi), axis=-1)


def head_predict(
    params: Dict[str, jax.Array],
    phi: jax.Array,
    edges: jax.Array,
    how: str = "median",
    impl: str = "auto",
) -> jax.Array:
    """Single-shot point prediction. ``median`` uses the fused kernel path."""
    if how == "median":
        _, med = ops.prod_head(
            phi, params["w1"], params["b1"], params["w2"], params["b2"], edges,
            impl=impl,
        )
        return med
    return decode_probs(head_probs(params, phi), edges, how)


def head_quantiles(
    params: Dict[str, jax.Array],
    phi: jax.Array,
    edges: jax.Array,
    qs,
    impl: str = "auto",
):
    """Fused distributional inference: one head evaluation returning the full
    histogram AND every requested predictive quantile.

    ``qs`` is a sequence of CDF levels (include 0.5 to get the median).
    Returns ``(probs (B, K), quants (B, len(qs)))`` — quantiles use the same
    CDF-crossing + in-bin interpolation decode as the fused median, so
    ``head_quantiles(..., qs=[0.5])[1][:, 0] == head_predict(..., "median")``.
    This is the one call :class:`~repro.serving.predictor.PredictorService`
    makes per dispatch batch."""
    return ops.prod_head(
        phi, params["w1"], params["b1"], params["w2"], params["b2"], edges,
        qs=jnp.asarray(qs, jnp.float32), impl=impl,
    )
