"""Theoretical analysis utilities (paper §2.3, Theorem 1, Appendix B).

Implements the linear ridge surrogate with median-of-r labels and the
quantities in Theorem 1, plus empirical validators:

* ``lemma3_moment`` — E|median(X_1..X_r)|^{1+ε} ≤ 2v (Lemma 3);
* ``failure_prob`` — the 4N·exp(−r/8) repeated-sampling failure term;
* ``r_required``   — r ≥ 8·log(4N/δ) making the bound hold w.p. ≥ 1−2δ;
* ``theorem1_bound`` — β_N and the per-point bound β_N·‖φ(x)‖_{V_N^{-1}}.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Tuple

import numpy as np


@dataclass
class RidgeFit:
    theta: np.ndarray          # (d,)
    vn: np.ndarray             # (d, d) = λI + Σ φφᵀ
    vn_inv: np.ndarray
    lam: float


def ridge_fit(phi: np.ndarray, labels: np.ndarray, lam: float = 1.0) -> RidgeFit:
    """θ̂_N = V_N^{-1} Σ_i L̄_i φ(x_i)  (App. B closed form)."""
    phi = np.asarray(phi, np.float64)
    d = phi.shape[1]
    vn = lam * np.eye(d) + phi.T @ phi
    vn_inv = np.linalg.inv(vn)
    theta = vn_inv @ (phi.T @ np.asarray(labels, np.float64))
    return RidgeFit(theta=theta, vn=vn, vn_inv=vn_inv, lam=lam)


def vn_norm(fit: RidgeFit, x: np.ndarray) -> np.ndarray:
    """‖φ(x)‖_{V_N^{-1}} — the self-normalized uncertainty term."""
    x = np.atleast_2d(np.asarray(x, np.float64))
    return np.sqrt(np.einsum("nd,de,ne->n", x, fit.vn_inv, x))


def failure_prob(N: int, r: int) -> float:
    return float(4.0 * N * np.exp(-r / 8.0))


def r_required(N: int, delta: float) -> int:
    return int(np.ceil(8.0 * np.log(4.0 * N / delta)))


def theorem1_constants(v: float, eps: float, N: int, delta: float) -> Tuple[float, float]:
    """C = (4v)^{1/(1+ε)},  ρ_δ = 2C ln(8N/δ) + 4C^{-ε} v."""
    C = (4.0 * v) ** (1.0 / (1.0 + eps))
    rho = 2.0 * C * np.log(8.0 * N / delta) + 4.0 * C ** (-eps) * v
    return C, rho


def theorem1_beta(
    N: int, d: int, v: float, eps: float, delta: float, lam: float, S: float
) -> float:
    """β_N = sqrt(ρ² N^{(1-ε)/(1+ε)} + 2Cρ d N^{(1-ε)/(1+ε)} log(1+N/λd)) + √λ S."""
    C, rho = theorem1_constants(v, eps, N, delta)
    pw = N ** ((1.0 - eps) / (1.0 + eps))
    return float(
        np.sqrt(rho**2 * pw + 2.0 * C * rho * d * pw * np.log(1.0 + N / (lam * d)))
        + np.sqrt(lam) * S
    )


def theorem1_pointwise_bound(fit: RidgeFit, x: np.ndarray, beta: float) -> np.ndarray:
    return beta * vn_norm(fit, x)


# ---------------------------------------------------------------------------
# empirical validators
# ---------------------------------------------------------------------------


def lemma3_moment(
    sampler: Callable[[np.random.Generator, Tuple[int, ...]], np.ndarray],
    r: int,
    eps: float,
    n_trials: int = 20000,
    seed: int = 0,
) -> Tuple[float, float]:
    """Empirical (E|X|^{1+ε}, E|median_r|^{1+ε}) — Lemma 3 says the second is
    ≤ 2× the first for symmetric X."""
    rng = np.random.default_rng(seed)
    x = sampler(rng, (n_trials, r))
    base = float(np.mean(np.abs(x[:, 0]) ** (1.0 + eps)))
    med = np.median(x, axis=1)
    med_moment = float(np.mean(np.abs(med) ** (1.0 + eps)))
    return base, med_moment


def median_label_noise(lengths: np.ndarray, true_median: np.ndarray) -> np.ndarray:
    """η̄_i = median(L_i1..L_ir) − median*(x_i): the label noise Theorem 1 controls."""
    return np.median(lengths, axis=1) - true_median


def empirical_coverage(
    fit: RidgeFit, phi_test: np.ndarray, true_vals: np.ndarray, beta: float
) -> float:
    """Fraction of test points with |φᵀθ* − φᵀθ̂| ≤ β‖φ‖_{V_N^{-1}} (should be
    ≥ 1−2δ when r ≥ r_required)."""
    pred = phi_test @ fit.theta
    bound = theorem1_pointwise_bound(fit, phi_test, beta)
    return float(np.mean(np.abs(pred - true_vals) <= bound))
