"""Cross-entropy losses over the K-bin grid (paper §2.4).

``soft_ce`` covers both variants: with a one-hot target it is ProD-M's
standard CE; with a histogram target it is ProD-D's distributional soft CE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def soft_ce(logits: jax.Array, target: jax.Array) -> jax.Array:
    """-(1/N) Σ_i Σ_k target_i(k) log q(k|x_i)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(target * logp, axis=-1))
