"""The paper's primary contribution: ProD — robust length prediction from
heavy-tailed prompt-conditioned length distributions.

* ``bins``      — length-bin grids, b(L) mapping, distribution decoders
* ``targets``   — repeated-sampling supervision targets (ProD-M / ProD-D / single)
* ``heads``     — the shared 2-layer MLP predictor head (paper 2.4)
* ``losses``    — CE / soft-CE
* ``predictor`` — training + single-shot inference wrapper
* ``baselines`` — Constant-Median, S3, TRAIL-mean/last, EGTP probes
* ``theory``    — ridge surrogate, Theorem 1 bound, Lemma 3 moment check
* ``metrics``   — MAE, noise radius (Median-MAE), heavy-tail diagnostics
"""
