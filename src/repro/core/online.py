"""ProD-O: online remaining-length prediction (beyond paper; its §5 roadmap).

The paper's formulation (§2.2) already covers t > 0: after t output tokens the
state z_t = {x, y_1..y_t} induces a distribution P(L_t | φ(z_t)) over the
REMAINING length. Repeated sampling per state is not available online (each
trajectory visits its states once), so supervision is single-draw — but the
predictor still outputs a K-bin distribution trained by CE and decoded by the
median (ProD's robust decode), TRAIL-style with ProD machinery.

This module builds the (φ(z_t), L − t) dataset from RealEngine generations,
trains the same head, and evaluates remaining-length MAE as a function of t —
the expected signature is error shrinking as decoding progresses, beating the
static prompt-only baseline max(median − t, 0).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PredictorConfig
from repro.core import bins as bins_mod
from repro.core.metrics import mae
from repro.core.predictor import LengthPredictor, train_predictor
from repro.core.targets import build_target


def build_online_dataset(
    step_hidden: np.ndarray,   # (B, T, d) per-step decode hidden states
    step_valid: np.ndarray,    # (B, T) bool
    lengths: np.ndarray,       # (B,) realized generation lengths
    stride: int = 1,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten trajectories into (phi (N,d), remaining (N,), t (N,), b (N,))."""
    B, T, d = step_hidden.shape
    phis, rem, ts, bs = [], [], [], []
    for b in range(B):
        L = int(lengths[b])
        for t in range(0, min(L, T), stride):
            if not step_valid[b, t]:
                continue
            phis.append(step_hidden[b, t])
            rem.append(L - (t + 1))
            ts.append(t + 1)
            bs.append(b)
    return (np.stack(phis).astype(np.float32),
            np.asarray(rem, np.float32), np.asarray(ts, np.int64),
            np.asarray(bs, np.int64))


def train_online_predictor(
    key: jax.Array,
    phi: np.ndarray,
    remaining: np.ndarray,
    pcfg: PredictorConfig,
) -> LengthPredictor:
    edges = bins_mod.make_edges(pcfg.n_bins, pcfg.bin_max, pcfg.bin_spacing)
    target = build_target(jnp.asarray(remaining)[:, None], edges, "single")
    return train_predictor(key, jnp.asarray(phi), target, pcfg, edges)


def evaluate_by_progress(
    predictor: LengthPredictor,
    phi: np.ndarray,
    remaining: np.ndarray,
    ts: np.ndarray,
    static_total_pred: Optional[np.ndarray] = None,   # per-sample prompt-only L̂
    n_buckets: int = 4,
) -> Dict[str, Dict[int, float]]:
    """Remaining-length MAE bucketed by decode progress t; compares the online
    head against the static baseline max(L̂_prompt − t, 0)."""
    pred = np.asarray(predictor.predict(jnp.asarray(phi)))
    out: Dict[str, Dict[int, float]] = {"online": {}, "static": {}, "count": {}}
    edges = np.quantile(ts, np.linspace(0, 1, n_buckets + 1))
    for i in range(n_buckets):
        m = (ts >= edges[i]) & (ts <= edges[i + 1] if i == n_buckets - 1
                                else ts < edges[i + 1])
        if not m.any():
            continue
        lo = int(edges[i])
        out["online"][lo] = float(np.mean(np.abs(pred[m] - remaining[m])))
        out["count"][lo] = int(m.sum())
        if static_total_pred is not None:
            stat = np.maximum(static_total_pred[m] - ts[m], 0.0)
            out["static"][lo] = float(np.mean(np.abs(stat - remaining[m])))
    return out
