"""ProD-O: online remaining-length prediction (beyond paper; its §5 roadmap).

The paper's formulation (§2.2) already covers t > 0: after t output tokens the
state z_t = {x, y_1..y_t} induces a distribution P(L_t | φ(z_t)) over the
REMAINING length. Repeated sampling per state is not available online (each
trajectory visits its states once), so supervision is single-draw — but the
predictor still outputs a K-bin distribution trained by CE and decoded by the
median (ProD's robust decode), TRAIL-style with ProD machinery.

This module builds the (φ(z_t), L − t) dataset from RealEngine generations,
trains the same head, and evaluates remaining-length MAE as a function of t —
the expected signature is error shrinking as decoding progresses, beating the
static prompt-only baseline max(median − t, 0).

It also hosts the serving-side half of that idea: :class:`PosteriorRefiner`
conditions a request's dispatch-time ProD-D histogram on the tokens it has
already emitted (P[L = ℓ | L > t] by truncate-and-renormalize, with an
optional learned hazard-rate correction), so scheduler keys and KV
reservations can re-read refreshed quantiles mid-flight instead of trusting
the prompt-only estimate forever.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PredictorConfig
from repro.core import bins as bins_mod
from repro.core.metrics import mae
from repro.core.predictor import LengthPredictor, train_predictor
from repro.core.targets import build_target


def build_online_dataset(
    step_hidden: np.ndarray,   # (B, T, d) per-step decode hidden states
    step_valid: np.ndarray,    # (B, T) bool
    lengths: np.ndarray,       # (B,) realized generation lengths
    stride: int = 1,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten trajectories into (phi (N,d), remaining (N,), t (N,), b (N,))."""
    B, T, d = step_hidden.shape
    phis, rem, ts, bs = [], [], [], []
    for b in range(B):
        L = int(lengths[b])
        for t in range(0, min(L, T), stride):
            if not step_valid[b, t]:
                continue
            phis.append(step_hidden[b, t])
            rem.append(L - (t + 1))
            ts.append(t + 1)
            bs.append(b)
    return (np.stack(phis).astype(np.float32),
            np.asarray(rem, np.float32), np.asarray(ts, np.int64),
            np.asarray(bs, np.int64))


def train_online_predictor(
    key: jax.Array,
    phi: np.ndarray,
    remaining: np.ndarray,
    pcfg: PredictorConfig,
) -> LengthPredictor:
    edges = bins_mod.make_edges(pcfg.n_bins, pcfg.bin_max, pcfg.bin_spacing)
    target = build_target(jnp.asarray(remaining)[:, None], edges, "single")
    return train_predictor(key, jnp.asarray(phi), target, pcfg, edges)


# ---------------------------------------------------------------------------
# Posterior refinement: condition the dispatch histogram on survival to t
# ---------------------------------------------------------------------------

# survivor mass below this is treated as "t is at/past the histogram support":
# renormalizing residual float dust would decode garbage quantiles, so the
# refiner degenerates to an explicit point mass at the cap instead
_SURVIVOR_EPS = 1e-12


def _hazard_features(ts: np.ndarray, cap: float) -> np.ndarray:
    """Feature map φ(t) the hazard head conditions on: progress in log and
    linear scale plus a bias channel (tiny on purpose — the head must work
    from repeated-generation traces of a few thousand lengths)."""
    t = np.asarray(ts, np.float64)
    return np.stack([np.log1p(t), t / max(float(cap), 1.0),
                     np.sqrt(np.maximum(t, 0.0)) / np.sqrt(max(cap, 1.0)),
                     np.ones_like(t)], axis=-1).astype(np.float32)


@dataclass
class HazardTable:
    """Learned hazard-rate correction, pre-evaluated on a progress grid.

    ``probs[g]`` is the head's estimate of the *population* conditional
    distribution P[L ∈ bin_k | L > ts[g]] and ``prior`` the population
    marginal it was fit against. The refiner turns the pair into a
    multiplicative correction on naive truncation:

        c_k(t) = probs[g(t)]_k / truncate-renorm(prior, t)_k

    i.e. how much the *realized* survival law deviates from truncating the
    prompt-only marginal — systematic head miscalibration as a function of
    progress. The grid is evaluated once at fit time (one fused-kernel
    batch), so refine ticks stay pure NumPy lookups.
    """

    ts: np.ndarray                       # (G,) sorted progress grid
    probs: np.ndarray                    # (G, K) conditional distributions
    prior: np.ndarray                    # (K,) population marginal histogram
    clip: Tuple[float, float] = (0.25, 4.0)

    def row(self, t: float) -> np.ndarray:
        g = int(np.searchsorted(self.ts, float(t), side="right")) - 1
        return self.probs[min(max(g, 0), len(self.ts) - 1)]


def fit_hazard_table(
    key: jax.Array,
    pred_probs: np.ndarray,    # (N, K) dispatch-time predictive histograms
    lengths: np.ndarray,       # (N,) realized decode lengths
    edges: np.ndarray,         # (K+1,) the serving head's bin edges
    t_grid: Optional[Sequence[int]] = None,
    hidden: int = 32,
    epochs: int = 30,
    clip: Tuple[float, float] = (0.25, 4.0),
) -> HazardTable:
    """Fit the hazard-rate correction head from repeated-generation traces.

    Builds (φ(t), L) pairs for every trace length that survived past each
    grid point t, trains the shared 2-layer head (:mod:`repro.core.heads`
    via :func:`repro.core.predictor.train_predictor`) on single-draw CE
    targets, and evaluates it over the grid through the fused quantile
    kernel — the same inference path the serving head uses.
    """
    edges = np.asarray(edges, np.float64)
    lengths = np.asarray(lengths, np.float64)
    cap = float(edges[-1])
    if t_grid is None:
        # log-spaced progress checkpoints, deduplicated after int-rounding
        g = np.unique(np.round(np.geomspace(1.0, max(cap / 2.0, 2.0), 24))
                      .astype(np.int64))
        t_grid = [0] + list(g)
    ts, ls = [], []
    for t in t_grid:
        alive = lengths[lengths > t]
        ts.extend([float(t)] * len(alive))
        ls.extend(alive.tolist())
    phi = _hazard_features(np.asarray(ts), cap)
    pcfg = PredictorConfig(n_bins=len(edges) - 1, hidden=hidden,
                           bin_max=int(cap), bin_spacing="log",
                           target="dist", epochs=epochs)
    target = build_target(jnp.asarray(ls)[:, None], jnp.asarray(edges),
                          "single")
    head = train_predictor(key, jnp.asarray(phi), target, pcfg,
                           jnp.asarray(edges))
    grid = np.asarray(sorted(set(float(t) for t in t_grid)), np.float64)
    gp, _ = head.quantiles(jnp.asarray(_hazard_features(grid, cap)),
                           qs=(0.5,))
    return HazardTable(ts=grid, probs=np.asarray(gp, np.float64),
                       prior=np.asarray(pred_probs, np.float64).mean(0),
                       clip=clip)


@dataclass
class PosteriorRefiner:
    """Mid-flight posterior over a request's total decode length.

    Given the dispatch-time ProD-D histogram ``p`` over ``edges`` and the
    ``t`` tokens the request has already emitted, the refiner returns the
    truncated-and-renormalized conditional P[L = ℓ | L > t]: bins fully
    below ``t`` get zero mass, the bin straddling ``t`` keeps the fraction
    of its width above ``t`` (the same uniform-within-bin model the
    CDF-crossing quantile decode interpolates with), and the rest is
    renormalized by the survivor mass S(t) = P[L > t].

    Quantiles decode from that conditional CDF with in-bin linear
    interpolation — consistent with :func:`repro.core.bins.decode_median` /
    the fused kernel at t = 0 — so every refreshed quantile is a *total*
    length, never below ``t``, and monotone in ``t``. When ``t`` is at or
    past the histogram support (S(t) ≈ 0) the posterior is an explicit
    degenerate point mass at the cap rather than a NaN-prone
    renormalization: every quantile returns ``max(cap, t + 1)``.

    ``hazard`` (a :class:`HazardTable`) multiplies the truncated mass by a
    learned, clipped correction for systematic deviation of realized
    survival from naive truncation; ``None`` is pure truncate-renorm.
    """

    edges: np.ndarray
    work_quantile: float = 0.9
    cap: Optional[float] = None
    hazard: Optional[HazardTable] = None

    def __post_init__(self):
        self.edges = np.asarray(self.edges, np.float64)
        if self.edges.ndim != 1 or len(self.edges) < 2:
            raise ValueError("edges must be a 1-D array of >= 2 bin edges")
        if not (0.0 < self.work_quantile < 1.0):
            raise ValueError("work_quantile must be in (0, 1)")
        self.cap = float(self.cap if self.cap is not None else self.edges[-1])

    # -- conditional mass ----------------------------------------------------

    def _mass(self, probs: np.ndarray, t: float) -> np.ndarray:
        """Unnormalized truncated (and hazard-corrected) bin masses."""
        e = self.edges
        lo, hi = e[:-1], e[1:]
        frac = np.clip((hi - float(t)) / np.maximum(hi - lo, 1e-300),
                       0.0, 1.0)
        m = np.asarray(probs, np.float64) * frac
        hz = self.hazard
        if hz is not None and m.sum() > _SURVIVOR_EPS:
            ref = np.asarray(hz.prior, np.float64) * frac
            s = ref.sum()
            if s > _SURVIVOR_EPS:
                c = np.clip(hz.row(t) / np.maximum(ref / s, 1e-12),
                            hz.clip[0], hz.clip[1])
                m = m * np.where(frac > 0.0, c, 1.0)
        return m

    def survivor(self, probs: np.ndarray, t: float) -> float:
        """S(t) = P[L > t] under the *uncorrected* dispatch histogram."""
        e = self.edges
        frac = np.clip((e[1:] - float(t)) / np.maximum(e[1:] - e[:-1], 1e-300),
                       0.0, 1.0)
        return float((np.asarray(probs, np.float64) * frac).sum())

    def condition(self, probs: np.ndarray, t: float) -> np.ndarray:
        """P[L ∈ bin_k | L > t] — a proper distribution for every t ≥ 0.

        Degenerate case (t at/past support): point mass in the last bin."""
        m = self._mass(probs, t)
        s = float(m.sum())
        if s <= _SURVIVOR_EPS:
            out = np.zeros(len(self.edges) - 1, np.float64)
            out[-1] = 1.0
            return out
        return m / s

    # -- quantile decode -----------------------------------------------------

    def quantiles(self, probs: np.ndarray, t: float, qs) -> np.ndarray:
        """Posterior *total-length* quantiles at CDF levels ``qs``.

        CDF-crossing + in-bin linear interpolation over the conditional
        histogram; the crossing bin interpolates from ``max(edge, t)`` so
        results are always ≥ t, clamped into [t, max(cap, t + 1)]."""
        t = float(t)
        m = self._mass(probs, t)
        s = float(m.sum())
        hi_clamp = max(self.cap, t + 1.0)
        out = np.empty(len(tuple(qs)), np.float64)
        if s <= _SURVIVOR_EPS:
            out[:] = hi_clamp          # degenerate point mass at the cap
            return out
        cum = np.cumsum(m)
        e = self.edges
        for j, q in enumerate(qs):
            tgt = float(q) * s
            k = int(np.searchsorted(cum, tgt, side="left"))
            k = min(k, len(m) - 1)
            prev = cum[k - 1] if k else 0.0
            left = max(float(e[k]), t)
            right = float(e[k + 1])
            f = 0.0 if m[k] <= _SURVIVOR_EPS \
                else min(max((tgt - prev) / m[k], 0.0), 1.0)
            out[j] = min(max(left + f * (right - left), t), hi_clamp)
        return out

    def quantile(self, probs: np.ndarray, t: float, q: float) -> float:
        return float(self.quantiles(probs, t, (q,))[0])

    def level_of(self, probs: np.ndarray, value: float) -> float:
        """Inverse decode: the CDF level of ``value`` under the *dispatch*
        histogram (in-bin linear interpolation). Recovers the effective
        quantile level a reservation was cut at — e.g. an OnlineAdapter's
        ACI-adjusted ``q_eff`` — so refinement can re-cut the reservation
        at the same conformal level on the posterior."""
        e = self.edges
        p = np.asarray(probs, np.float64)
        v = float(value)
        if v <= float(e[0]):
            return 0.0
        if v >= float(e[-1]):
            return 1.0
        k = int(np.searchsorted(e, v, side="right")) - 1
        k = min(max(k, 0), len(p) - 1)
        cum = float(p[:k].sum())
        width = float(e[k + 1] - e[k])
        frac = (v - float(e[k])) / width if width > 0 else 1.0
        return min(max(cum + float(p[k]) * frac, 0.0), 1.0)


def evaluate_by_progress(
    predictor: LengthPredictor,
    phi: np.ndarray,
    remaining: np.ndarray,
    ts: np.ndarray,
    static_total_pred: Optional[np.ndarray] = None,   # per-sample prompt-only L̂
    n_buckets: int = 4,
) -> Dict[str, Dict[int, float]]:
    """Remaining-length MAE bucketed by decode progress t; compares the online
    head against the static baseline max(L̂_prompt − t, 0)."""
    pred = np.asarray(predictor.predict(jnp.asarray(phi)))
    out: Dict[str, Dict[int, float]] = {"online": {}, "static": {}, "count": {}}
    edges = np.quantile(ts, np.linspace(0, 1, n_buckets + 1))
    for i in range(n_buckets):
        m = (ts >= edges[i]) & (ts <= edges[i + 1] if i == n_buckets - 1
                                else ts < edges[i + 1])
        if not m.any():
            continue
        lo = int(edges[i])
        out["online"][lo] = float(np.mean(np.abs(pred[m] - remaining[m])))
        out["count"][lo] = int(m.sum())
        if static_total_pred is not None:
            stat = np.maximum(static_total_pred[m] - ts[m], 0.0)
            out["static"][lo] = float(np.mean(np.abs(stat - remaining[m])))
    return out
