"""Length-bin grids and distribution→point decoders (paper §2.4).

The predictor outputs a distribution over K length bins. The paper decodes a
point estimate as the *median* of the predictive distribution — the CDF 0.5
crossing with linear interpolation inside the crossing bin — arguing it is
more robust than the argmax bin center or the expectation when the predicted
distribution is heavy-tailed/skewed. All three decoders are provided.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def linear_edges(n_bins: int, bin_max: float, bin_min: float = 0.0) -> jnp.ndarray:
    return jnp.linspace(bin_min, bin_max, n_bins + 1)


def log_edges(n_bins: int, bin_max: float, bin_min: float = 1.0) -> jnp.ndarray:
    """Log-spaced edges — a beyond-paper option that matches heavy tails."""
    e = jnp.exp(jnp.linspace(jnp.log(bin_min), jnp.log(bin_max), n_bins + 1))
    return e.at[0].set(0.0)


def make_edges(n_bins: int, bin_max: float, spacing: str = "linear") -> jnp.ndarray:
    if spacing == "linear":
        return linear_edges(n_bins, bin_max)
    if spacing == "log":
        return log_edges(n_bins, bin_max)
    raise ValueError(spacing)


def bin_index(lengths: jax.Array, edges: jax.Array) -> jax.Array:
    """b(L): map lengths to bin ids in [0, K-1] (overflow clamps to last bin)."""
    K = edges.shape[0] - 1
    idx = jnp.searchsorted(edges, lengths, side="right") - 1
    return jnp.clip(idx, 0, K - 1)


def bin_centers(edges: jax.Array) -> jax.Array:
    return 0.5 * (edges[:-1] + edges[1:])


def decode_median(probs: jax.Array, edges: jax.Array) -> jax.Array:
    """Median of the predictive distribution with in-bin interpolation."""
    K = probs.shape[-1]
    cdf = jnp.cumsum(probs, axis=-1)
    k_star = jnp.argmax(cdf >= 0.5, axis=-1)
    take = lambda arr, i: jnp.take_along_axis(arr, i[..., None], axis=-1)[..., 0]
    cdf_prev = jnp.where(k_star > 0, take(cdf, jnp.maximum(k_star - 1, 0)), 0.0)
    p_k = take(probs, k_star)
    t = jnp.clip((0.5 - cdf_prev) / jnp.maximum(p_k, 1e-12), 0.0, 1.0)
    left = edges[k_star]
    right = edges[k_star + 1]
    return left + t * (right - left)


def decode_mean(probs: jax.Array, edges: jax.Array) -> jax.Array:
    return probs @ bin_centers(edges)


def decode_argmax(probs: jax.Array, edges: jax.Array) -> jax.Array:
    return bin_centers(edges)[jnp.argmax(probs, axis=-1)]


DECODERS = {"median": decode_median, "mean": decode_mean, "argmax": decode_argmax}


def decode(probs: jax.Array, edges: jax.Array, how: str) -> jax.Array:
    return DECODERS[how](probs, edges)
