"""Repeated-sampling supervision targets (paper §2.3–2.4).

Given r independent generations per prompt with lengths ``L (N, r)``:

* **ProD-M**: one-hot of the binned sample median — compresses the heavy tail
  into a robust point target aligned with the MAE-Bayes-optimal conditional
  median.
* **ProD-D**: the binned empirical histogram — preserves the full
  prompt-conditioned uncertainty as a soft target.
* **single**: one-hot of a single sampled length — the (statistically
  misaligned) supervision all prior methods use; kept for the ablations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bins import bin_index


def sample_median(lengths: jax.Array) -> jax.Array:
    """Sample median over the repeat axis. lengths: (N, r) -> (N,)."""
    return jnp.median(lengths.astype(jnp.float32), axis=-1)


def median_target(lengths: jax.Array, edges: jax.Array) -> jax.Array:
    """ProD-M: y_med one-hot (N, K)."""
    K = edges.shape[0] - 1
    med = sample_median(lengths)
    return jax.nn.one_hot(bin_index(med, edges), K, dtype=jnp.float32)


def dist_target(lengths: jax.Array, edges: jax.Array) -> jax.Array:
    """ProD-D: p_dist (N, K); p_i(k) = (1/r) Σ_j 1[b(L_ij)=k]."""
    K = edges.shape[0] - 1
    idx = bin_index(lengths, edges)                       # (N, r)
    return jnp.mean(jax.nn.one_hot(idx, K, dtype=jnp.float32), axis=1)


def single_target(lengths: jax.Array, edges: jax.Array, which: int = 0) -> jax.Array:
    """One-shot label (ablation): one-hot of the ``which``-th sample."""
    K = edges.shape[0] - 1
    one = lengths[:, which].astype(jnp.float32)
    return jax.nn.one_hot(bin_index(one, edges), K, dtype=jnp.float32)


def build_target(lengths: jax.Array, edges: jax.Array, kind: str,
                 single_idx: int = 0) -> jax.Array:
    if kind == "median":
        return median_target(lengths, edges)
    if kind == "dist":
        return dist_target(lengths, edges)
    if kind == "single":
        return single_target(lengths, edges, single_idx)
    raise ValueError(kind)
