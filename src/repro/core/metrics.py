"""Evaluation metrics and heavy-tail diagnostics (paper §3.1, A.1, A.4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mae(pred: jax.Array, target: jax.Array) -> float:
    return float(jnp.mean(jnp.abs(pred.astype(jnp.float32) - target.astype(jnp.float32))))


def median_mae_per_prompt(lengths: jax.Array) -> jax.Array:
    """Prompt-level Median-MAE (A.1): (1/R) Σ_r |L_ir - median_i|. (N, R) -> (N,)."""
    med = jnp.median(lengths.astype(jnp.float32), axis=-1, keepdims=True)
    return jnp.mean(jnp.abs(lengths.astype(jnp.float32) - med), axis=-1)


def noise_radius(lengths: jax.Array) -> float:
    """The Noise Radius reference line: mean prompt-level Median-MAE."""
    return float(jnp.mean(median_mae_per_prompt(lengths)))


def max_to_median(lengths: jax.Array) -> jax.Array:
    """Heavy-tail diagnostic (A.4): max(length)/median(length) per prompt."""
    l32 = lengths.astype(jnp.float32)
    med = jnp.median(l32, axis=-1)
    return jnp.max(l32, axis=-1) / jnp.maximum(med, 1.0)


def noise_ratio(lengths: jax.Array) -> jax.Array:
    """Median-MAE normalized by the prompt median (the 11.5%–18.2% figure)."""
    med = jnp.median(lengths.astype(jnp.float32), axis=-1)
    return median_mae_per_prompt(lengths) / jnp.maximum(med, 1.0)


def hill_tail_index(samples: np.ndarray, k_frac: float = 0.1) -> float:
    """Hill estimator of the tail index α on the pooled upper tail.

    Smaller α = heavier tail; α ≤ 2 implies infinite variance. Used to check
    the "consistent with heavy-tailed behavior" claim quantitatively.
    """
    x = np.sort(np.asarray(samples, dtype=np.float64).ravel())
    x = x[x > 0]
    n = len(x)
    k = max(int(n * k_frac), 2)
    tail = x[n - k :]
    logs = np.log(tail) - np.log(tail[0])
    return float(1.0 / np.mean(logs[1:])) if np.mean(logs[1:]) > 0 else float("inf")


def summarize_run(name: str, pred, target) -> dict:
    return {"method": name, "mae": mae(pred, target)}
