"""LengthPredictor: train the shared head on repeated-sampling targets and
serve single-shot point predictions (paper §2.4).

``train_predictor`` is the one function every method variant goes through —
ProD-M / ProD-D / single-sample baselines differ ONLY in the target matrix
and decode rule, which is exactly the paper's controlled comparison.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PredictorConfig
from repro.core import bins as bins_mod
from repro.core.heads import (head_init, head_logits, head_predict,
                              head_probs, head_quantiles)
from repro.core.losses import soft_ce
from repro.training.optim import adamw, Optimizer
from repro.common.config import TrainConfig

# one (Optimizer, jitted step) pair per optimizer config, shared across every
# train_predictor call — the step closure used to be rebuilt (and re-jitted)
# per call, so training N heads paid N compiles even at identical shapes
_STEP_CACHE: Dict[TrainConfig, tuple] = {}


def _opt_and_step(tcfg: TrainConfig):
    hit = _STEP_CACHE.get(tcfg)
    if hit is None:
        opt = adamw(tcfg)

        @jax.jit
        def step(params, state, x, y, i):
            loss, grads = jax.value_and_grad(
                lambda p: soft_ce(head_logits(p, x), y)
            )(params)
            params, state = opt.update(grads, state, params, i)
            return params, state, loss

        hit = (opt, step)
        _STEP_CACHE[tcfg] = hit
    return hit


@dataclass
class LengthPredictor:
    params: Dict[str, jax.Array]
    edges: jax.Array
    pcfg: PredictorConfig

    def predict(self, phi: jax.Array, how: Optional[str] = None) -> jax.Array:
        return head_predict(self.params, phi, self.edges, how or self.pcfg.decode)

    def predict_dist(self, phi: jax.Array) -> jax.Array:
        return head_probs(self.params, phi)

    def quantile(self, phi: jax.Array, q: float) -> jax.Array:
        """Predictive-distribution quantile (used for KV reservation).

        Conservative right-edge decode: returns the upper edge of the bin
        where the CDF crosses ``q`` (never under-reserves within the bin).
        For the interpolated variant see :meth:`quantiles`."""
        probs = self.predict_dist(phi)
        cdf = jnp.cumsum(probs, axis=-1)
        k = jnp.argmax(cdf >= q, axis=-1)
        return self.edges[k + 1]

    def quantiles(self, phi: jax.Array, qs, impl: str = "auto"):
        """Fused histogram + interpolated quantiles in ONE head evaluation.

        ``qs``: sequence of CDF levels. Returns ``(probs (B, K),
        quants (B, len(qs)))`` via the fused kernel path — what the serving
        :class:`~repro.serving.predictor.PredictorService` calls per dispatch
        batch instead of one :meth:`quantile` pass per level."""
        return head_quantiles(self.params, phi, self.edges, qs, impl=impl)


def train_predictor(
    key: jax.Array,
    phi: jax.Array,            # (N, d) features
    target: jax.Array,         # (N, K) one-hot or histogram
    pcfg: PredictorConfig,
    edges: Optional[jax.Array] = None,
    verbose: bool = False,
    init_params: Optional[Dict[str, jax.Array]] = None,
) -> LengthPredictor:
    """Fit the shared 2-layer head on (features, binned target) pairs.

    ``init_params`` warm-starts from existing head weights (shapes must
    match) — the serving refresh path re-fits on a recent completion buffer
    this way. Warm starts take ``pcfg.epochs`` at face value; cold starts
    keep the ~400-optimizer-step floor so tiny datasets still converge.
    """
    N, d = phi.shape
    K = target.shape[1]
    if edges is None:
        edges = bins_mod.make_edges(pcfg.n_bins, pcfg.bin_max, pcfg.bin_spacing)
    params = head_init(key, d, pcfg.hidden, K) if init_params is None \
        else init_params
    opt, step = _opt_and_step(
        TrainConfig(optimizer="adamw", lr=pcfg.lr, schedule="constant",
                    warmup_steps=1, weight_decay=pcfg.weight_decay,
                    beta1=0.9, beta2=0.999))
    state = opt.init(params)
    bs = min(pcfg.batch_size, N)
    steps_per_epoch = max(N // bs, 1)
    # small datasets need a step floor, not an epoch count (the head sees too
    # few updates otherwise) — keep at least ~400 optimizer steps on a cold
    # start; warm-started refits are incremental and run epochs as given
    min_epochs = -(-400 // steps_per_epoch) if init_params is None else 1
    n_epochs = max(pcfg.epochs, min_epochs)

    phi = jnp.asarray(phi, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    it = 0
    for epoch in range(n_epochs):
        perm = rng.permutation(N)
        for s in range(steps_per_epoch):
            idx = perm[s * bs : (s + 1) * bs]
            params, state, loss = step(params, state, phi[idx], target[idx],
                                       jnp.asarray(it, jnp.float32))
            it += 1
        if verbose and (epoch % 10 == 0 or epoch == n_epochs - 1):
            print(f"  epoch {epoch:3d}  soft-CE {float(loss):.4f}")
    return LengthPredictor(params=params, edges=edges, pcfg=pcfg)
