"""Baseline probes (paper §3.1) and the unified method runner.

Every trainable method is (feature view, training target, decode rule) over
the SAME 2-layer MLP head — the paper's controlled comparison:

| method          | φ view                  | target (Table 1) | decode  |
|-----------------|-------------------------|------------------|---------|
| Constant Median | —                       | train median     | const   |
| S³              | auxiliary proxy         | median one-hot   | argmax  | (+ num_bins/bin_max sweep, App. A.2)
| TRAIL-mean      | mean-pooled hidden      | median one-hot   | mean    |
| TRAIL-last      | last-token hidden       | median one-hot   | mean    |
| EGTP            | entropy-weighted pooled | median one-hot   | mean    | (+ num_bins sweep)
| ProD-M          | last-token hidden       | median one-hot   | median  |
| ProD-D          | last-token hidden       | histogram        | median  |

Supervision regimes: ``repeat`` (Table 1) trains on the 16-sample targets;
``single`` (Tables 2–3) trains every method on one sampled length.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PredictorConfig
from repro.core import bins as bins_mod
from repro.core import targets as targets_mod
from repro.core.metrics import mae
from repro.core.predictor import LengthPredictor, train_predictor

METHODS = (
    "constant_median", "s3", "trail_mean", "trail_last", "egtp",
    "prod_m", "prod_d",
)

_VIEW = {
    "s3": "proxy", "trail_mean": "mean", "trail_last": "last",
    "egtp": "entropy", "prod_m": "last", "prod_d": "last",
}
_DECODE = {
    "s3": "argmax", "trail_mean": "mean", "trail_last": "mean",
    "egtp": "mean", "prod_m": "median", "prod_d": "median",
}
_TARGET = {
    "s3": "median", "trail_mean": "median", "trail_last": "median",
    "egtp": "median", "prod_m": "median", "prod_d": "dist",
}

S3_NUM_BINS_GRID = (7, 10, 13, 15, 20)


@dataclass
class MethodResult:
    method: str
    test_mae: float
    pred: np.ndarray
    predictor: Optional[LengthPredictor] = None
    selected: Optional[dict] = None


def _bin_max_grid(train_lengths: np.ndarray) -> Sequence[float]:
    """Scene-adaptive bin_max grid in the spirit of App. A.2 (p95–p99.9×1.3)."""
    hi = float(np.quantile(train_lengths, 0.999)) * 1.3
    lo = float(np.quantile(train_lengths, 0.95))
    return tuple(np.linspace(lo, hi, 4))


def run_method(
    key: jax.Array,
    data,                       # repro.data ScenarioData
    method: str,
    pcfg: PredictorConfig,
    supervision: str = "repeat",     # repeat | single
    single_idx: int = 0,
    eval_target: str = "median",     # median | single
) -> MethodResult:
    len_train = jnp.asarray(data.len_train, jnp.float32)   # (N, r)
    len_test = jnp.asarray(data.len_test, jnp.float32)     # (Nt, r)
    if eval_target == "median":
        y_test = targets_mod.sample_median(len_test)
    else:
        y_test = len_test[:, single_idx]

    if method == "constant_median":
        const = float(jnp.median(targets_mod.sample_median(len_train)))
        pred = np.full(len_test.shape[0], const, np.float32)
        return MethodResult(method, mae(jnp.asarray(pred), y_test), pred,
                            selected={"constant": const})

    view = _VIEW[method]
    phi_tr = jnp.asarray(data.phi_train[view], jnp.float32)
    phi_te = jnp.asarray(data.phi_test[view], jnp.float32)

    target_kind = _TARGET[method] if supervision == "repeat" else "single"
    if method == "prod_d" and supervision == "single":
        raise ValueError("ProD-D is undefined under single-sample supervision "
                         "(degenerate distribution target) — paper §3.3")
    decode_rule = _DECODE[method]

    def fit_eval(n_bins: int, bin_max: float, k):
        edges = bins_mod.make_edges(n_bins, bin_max, pcfg.bin_spacing)
        tgt = targets_mod.build_target(len_train, edges, target_kind, single_idx)
        p = train_predictor(k, phi_tr, tgt,
                            dataclasses.replace(pcfg, n_bins=n_bins,
                                                bin_max=bin_max),
                            edges=edges)
        pred_tr = p.predict(phi_tr, decode_rule)
        y_tr = (targets_mod.sample_median(len_train)
                if supervision == "repeat" else len_train[:, single_idx])
        return p, mae(pred_tr, y_tr)

    selected = {}
    if method in ("s3", "egtp"):
        # hyper-parameter sweep on the train split (App. A.2 protocol)
        best = None
        grids = [(nb, bm) for nb in S3_NUM_BINS_GRID
                 for bm in (_bin_max_grid(np.asarray(len_train))
                            if method == "s3" else (pcfg.bin_max,))]
        keys = jax.random.split(key, len(grids))
        for (nb, bm), k in zip(grids, keys):
            p, train_mae = fit_eval(nb, float(bm), k)
            if best is None or train_mae < best[0]:
                best = (train_mae, p, {"num_bins": nb, "bin_max": float(bm)})
        _, predictor, selected = best
    else:
        predictor, _ = fit_eval(pcfg.n_bins, pcfg.bin_max, key)

    pred = predictor.predict(phi_te, decode_rule)
    return MethodResult(method, mae(pred, y_test), np.asarray(pred), predictor,
                        selected)
