"""Decoder-only transformer assembly for dense / MoE / SSM / hybrid / VLM.

Architectures are described by a *layer plan*: a list of segments, each a
``(kinds, n_blocks)`` pair scanned with ``lax.scan`` over stacked block
parameters. Within a block the (static, short) ``kinds`` tuple is unrolled:

* uniform dense:   [ (('full',), L) ]
* gemma3 5:1:      [ (('local',)*5 + ('full',), L//6), (('local',)*(L%6), 1) ]
* MoE:             [ (('moe',), L) ]
* mamba2:          [ (('ssm',), L) ]
* zamba2 hybrid:   [ (('shared_attn',) + ('ssm',)*k, L//k), (('ssm',)*(L%k), 1) ]

``shared_attn`` uses one weight-shared attention+MLP block (Zamba2) passed via
closure, while its KV cache *is* per-invocation (scanned).

Modes: ``forward`` (train), ``prefill`` (returns KV/SSM caches + last hidden
states for the ProD predictor), ``decode_step`` (one token, static shapes).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamSpec,
    embed_spec,
    init_tree,
    mlp_apply,
    mlp_spec,
    rms_norm,
    shape_tree,
    stack_specs,
    unembed,
)
from repro.models.moe import moe_apply, moe_spec
from repro.models.rope import rope_angles, positions_from_tokens, text_mrope_positions


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    kinds: Tuple[str, ...]
    n_blocks: int


def layer_plan(cfg: ModelConfig) -> List[Segment]:
    L = cfg.n_layers
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.local_global_ratio > 0:
            blk = ("local",) * cfg.local_global_ratio + ("full",)
            segs = []
            if L // len(blk):
                segs.append(Segment(blk, L // len(blk)))
            rem = L % len(blk)
            if rem:
                segs.append(Segment(("local",) * rem, 1))
            return segs
        kind = "moe" if cfg.family == "moe" else ("local" if cfg.attn_window else "full")
        return [Segment((kind,), L)]
    if cfg.family == "ssm":
        return [Segment(("ssm",), L)]
    if cfg.family == "hybrid":
        k = max(cfg.attn_every, 1)
        segs = []
        if L // k:
            segs.append(Segment(("shared_attn",) + ("ssm",) * k, L // k))
        if L % k:
            segs.append(Segment(("ssm",) * (L % k), 1))
        return segs
    raise ValueError(cfg.family)


def _attn_kind_window(cfg: ModelConfig, kind: str) -> int:
    if kind == "local":
        return cfg.attn_window
    if kind == "shared_attn":
        return cfg.attn_window  # zamba2 shared block rings at long context
    return 0


# ---------------------------------------------------------------------------
# parameter spec
# ---------------------------------------------------------------------------


def _layer_spec(cfg: ModelConfig, kind: str):
    norm = lambda: ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    if kind in ("full", "local"):
        return {"ln1": norm(), "attn": attn.attn_spec(cfg), "ln2": norm(),
                "mlp": mlp_spec(cfg.d_model, cfg.d_ff, cfg.act)}
    if kind == "moe":
        return {"ln1": norm(), "attn": attn.attn_spec(cfg), "ln2": norm(),
                "moe": moe_spec(cfg)}
    if kind == "ssm":
        return {"ln1": norm(), "ssm": ssm_mod.ssm_spec(cfg)}
    if kind == "shared_attn":
        return {}  # weights live in params["shared"], applied by closure
    raise ValueError(kind)


def shared_block_spec(cfg: ModelConfig):
    norm = lambda: ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    return {"ln1": norm(), "attn": attn.attn_spec(cfg), "ln2": norm(),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, "silu")}


def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    plan = layer_plan(cfg)
    spec: Dict[str, Any] = {"embed": embed_spec(cfg.vocab_size, cfg.d_model)}
    spec["final_norm"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        spec["head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    segs = []
    for seg in plan:
        block = {f"layer_{j}": _layer_spec(cfg, kind) for j, kind in enumerate(seg.kinds)}
        segs.append(stack_specs(block, seg.n_blocks))
    spec["segments"] = segs
    if cfg.family == "hybrid":
        spec["shared"] = shared_block_spec(cfg)
    return spec


# ---------------------------------------------------------------------------
# forward context
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Ctx:
    cfg: ModelConfig
    mesh: Any = None
    mode: str = "train"              # train | prefill | decode
    remat: str = "none"              # none | full
    block_q: int = 512
    block_kv: int = 512
    causal_skip: bool = False
    capacity_factor: float = 1.25
    moe_cap_slack: float = 2.0
    moe_fsdp_mode: str = "gather"
    kv_quant: bool = False
    seq_shard: bool = False
    cache_len: int = 0               # decode: allocated full-cache length
    window_cache_len: int = 0        # decode: allocated ring length


def _angles_for(cfg: ModelConfig, positions, kind: str):
    theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
    return rope_angles(positions, cfg.head_dim, theta, use_mrope=cfg.use_mrope)


def _precompute_angles(cfg: ModelConfig, plan, positions):
    """RoPE angle tables per rope-base, computed OUTSIDE layer scans (a cached
    tracer from one scan body must never leak into another)."""
    keys = set()
    for seg in plan:
        for kind in seg.kinds:
            if kind != "ssm":
                keys.add("local" if kind == "local" else "global")
    return {k: _angles_for(cfg, positions, k) for k in keys} or {"global": None}


# ---------------------------------------------------------------------------
# single-layer application (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _apply_attn_seq(lp, x, ctx: Ctx, kind: str, angles, attn_valid):
    cfg = ctx.cfg
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_project(lp["attn"], h, cfg, angles)
    o = attn.blocked_attention(
        q, k, v,
        causal=True,
        window=_attn_kind_window(cfg, kind),
        kv_valid=attn_valid,
        block_q=ctx.block_q,
        block_kv=ctx.block_kv,
        causal_skip=ctx.causal_skip,
    )
    x = x + attn.out_project(lp["attn"], o)
    return x, (k, v)


def _apply_ffn(lp, x, ctx: Ctx, ffn_kind: str):
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if ffn_kind == "moe":
        y, aux = moe_apply(lp["moe"], h, cfg, mesh=ctx.mesh,
                           capacity_factor=ctx.capacity_factor,
                           cap_slack=ctx.moe_cap_slack,
                           fsdp_mode=ctx.moe_fsdp_mode)
    else:
        y = mlp_apply(lp["mlp"], h, cfg.act)
    return x + y, aux


def _apply_layer_seq(lp, shared, x, ctx: Ctx, kind: str, angles, attn_valid):
    """Returns (x, cache_entry, aux)."""
    cfg = ctx.cfg
    if kind == "ssm":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if ctx.mode == "prefill":
            y, state = ssm_mod.ssm_prefill(lp["ssm"], h, cfg)
            return x + y, state, jnp.zeros((), jnp.float32)
        y = ssm_mod.ssm_apply_train(lp["ssm"], h, cfg)
        return x + y, None, jnp.zeros((), jnp.float32)
    p = shared if kind == "shared_attn" else lp
    x, (k, v) = _apply_attn_seq(p, x, ctx, kind, angles, attn_valid)
    x, aux = _apply_ffn(p, x, ctx, "moe" if kind == "moe" else "mlp")
    cache = None
    if ctx.mode == "prefill":
        W = _attn_kind_window(cfg, kind)
        cache = _ring_from_prefill(k, v, W) if W else {"k": k, "v": v}
    return x, cache, aux


def _ring_from_prefill(k, v, W: int):
    B, S, KV, hd = k.shape
    if S <= W:
        pad = W - S
        kr = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vr = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # token t sits at slot t % W == t for t < S — already aligned
        return {"k": kr, "v": vr}
    t = jnp.arange(S - W, S, dtype=jnp.int32)
    slots = jnp.mod(t, W)
    kr = jnp.zeros((B, W, KV, hd), k.dtype).at[:, slots].set(k[:, S - W :])
    vr = jnp.zeros((B, W, KV, hd), v.dtype).at[:, slots].set(v[:, S - W :])
    return {"k": kr, "v": vr}


# ---------------------------------------------------------------------------
# full-sequence pass (train / prefill)
# ---------------------------------------------------------------------------


def forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,        # (B, S) int32
    embeds: Optional[jax.Array] = None,        # (B, S, d) — VLM / audio stubs
    positions: Optional[jax.Array] = None,     # (B, S) or (3, B, S) for M-RoPE
    attn_valid: Optional[jax.Array] = None,    # (B, S) bool
    ctx: Optional[Ctx] = None,
    logits_mode: str = "all",                  # all | none (serving prefill)
):
    """Full-sequence pass. Returns (logits, hidden, cache_or_None, aux_loss).

    ``logits_mode="none"`` skips the (B, S, V) unembed entirely — serving
    prefill gathers the last-token hidden state and unembeds (B, V) itself.
    """
    ctx = ctx or Ctx(cfg=cfg)
    if embeds is None:
        embeds = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    B, S = embeds.shape[:2]
    if positions is None:
        positions = (
            text_mrope_positions(B, S) if cfg.use_mrope else positions_from_tokens(B, S)
        )
    plan = layer_plan(cfg)
    angle_map = _precompute_angles(cfg, plan, positions)
    angles = lambda kind: angle_map["local" if kind == "local" else "global"]

    shared = params.get("shared")
    x = embeds
    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for seg, seg_params in zip(plan, params["segments"]):

        def block_fn(carry, block_p, _kinds=seg.kinds):
            x, aux = carry
            entries = {}
            for j, kind in enumerate(_kinds):
                lp = block_p[f"layer_{j}"]
                x, cache, a = _apply_layer_seq(
                    lp, shared, x, ctx, kind, angles(kind), attn_valid
                )
                if ctx.mode == "prefill":
                    entries[f"layer_{j}"] = cache
                aux = aux + a
            if ctx.seq_shard and ctx.mesh is not None:
                # Megatron sequence parallelism: the saved residual (and the
                # norm/elementwise region around it) lives seq-sharded over
                # `model`; GSPMD inserts all-gather at attention entry and
                # reduce-scatter after — trades collective for 16× less saved
                # activation memory under remat
                from jax.sharding import NamedSharding, PartitionSpec as P
                data_axes = tuple(a for a in ("pod", "data")
                                  if a in ctx.mesh.axis_names)
                x = jax.lax.with_sharding_constraint(
                    x, NamedSharding(ctx.mesh, P(data_axes, "model", None)))
            return (x, aux), entries

        fn = jax.checkpoint(block_fn) if ctx.remat == "full" else block_fn
        (x, aux_total), seg_cache = jax.lax.scan(fn, (x, aux_total), seg_params)
        caches.append(seg_cache)

    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (unembed(hidden, params["embed"], params.get("head"))
              if logits_mode == "all" else None)
    cache = caches if ctx.mode == "prefill" else None
    return logits, hidden, cache, aux_total


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int,
               kv_quant: bool = False):
    """ParamSpec pytree (shapes + logical axes) for the decode cache.

    Windowed layers allocate a ring of ``min(window, cache_len)``; full layers
    allocate ``cache_len``. SSM layers carry (h, conv) state. With
    ``kv_quant`` the K/V tensors are int8 with per-(token, kv-head) fp32
    scales (beyond-paper serving optimization — halves decode cache reads).
    """
    plan = layer_plan(cfg)
    H, P, N, d_conv = (0, 0, 0, 0)
    if cfg.family in ("ssm", "hybrid"):
        H, P, N, d_conv = ssm_mod.ssm_dims(cfg)
    kv_ax = ("layers", "batch", "cache_seq", "cache_kv_heads", "head_dim")
    sc_ax = ("layers", "batch", "cache_seq", "cache_kv_heads")
    segs = []
    for seg in plan:
        entries = {}
        for j, kind in enumerate(seg.kinds):
            n = seg.n_blocks
            if kind == "ssm":
                entries[f"layer_{j}"] = {
                    "h": ParamSpec((n, batch, H, P, N),
                                   ("layers", "batch", "ssm_heads", None, None)),
                    "conv": ParamSpec((n, batch, cfg.ssm_conv_width - 1, d_conv),
                                      ("layers", "batch", None, "ssm_inner")),
                }
            else:
                W = _attn_kind_window(cfg, kind)
                Sc = min(W, cache_len) if W else cache_len
                kv_shape = (n, batch, Sc, cfg.n_kv_heads, cfg.head_dim)
                e = {
                    "k": ParamSpec(kv_shape, kv_ax,
                                   init="int8" if kv_quant else "normal"),
                    "v": ParamSpec(kv_shape, kv_ax,
                                   init="int8" if kv_quant else "normal"),
                }
                if kv_quant:
                    e["k_s"] = ParamSpec((n, batch, Sc, cfg.n_kv_heads), sc_ax,
                                         init="f32")
                    e["v_s"] = ParamSpec((n, batch, Sc, cfg.n_kv_heads), sc_ax,
                                         init="f32")
                entries[f"layer_{j}"] = e
        segs.append(entries)
    return segs


def cache_dtype(spec: ParamSpec, dtype):
    """SSM h-state + quant scales fp32; int8 for quantized K/V; model dtype else."""
    if spec.init == "int8":
        return jnp.int8
    if spec.init == "f32" or spec.axes[2:3] == ("ssm_heads",):
        return jnp.float32
    return jnp.dtype(dtype)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    spec = cache_spec(cfg, batch, cache_len)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, cache_dtype(s, dtype)), spec,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def _quantize_kv(t):
    """(B, KV, hd) -> (int8 values, fp32 scales (B, KV))."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(t.astype(jnp.float32) / jnp.maximum(scale[..., None], 1e-8))
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _decode_attn_layer(lp, x, entry, ctx: Ctx, kind: str, pos, lengths, angles):
    """One cached attention layer for a single new token."""
    cfg = ctx.cfg
    B = x.shape[0]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_project(lp["attn"], h, cfg, angles)  # (B,1,·,hd)
    W = _attn_kind_window(cfg, kind)
    Sc = entry["k"].shape[1]
    ring = bool(W) and Sc == W  # ring iff allocated exactly the window
    bidx = jnp.arange(B)
    quant = "k_s" in entry
    if quant:
        k_new, ks_new = _quantize_kv(k[:, 0])
        v_new, vs_new = _quantize_kv(v[:, 0])
    else:
        k_new, v_new = k[:, 0], v[:, 0]
    slot = jnp.mod(pos, Sc) if ring else pos
    kc = entry["k"].at[bidx, slot].set(k_new.astype(entry["k"].dtype))
    vc = entry["v"].at[bidx, slot].set(v_new.astype(entry["v"].dtype))
    new_entry = {"k": kc, "v": vc}
    if quant:
        new_entry["k_s"] = entry["k_s"].at[bidx, slot].set(ks_new)
        new_entry["v_s"] = entry["v_s"].at[bidx, slot].set(vs_new)
    if ring:
        valid = attn.ring_cache_valid(lengths, Sc)
    else:
        valid = attn.full_cache_valid(lengths, Sc)
        if W:  # windowed semantics on a full cache
            kpos = jnp.arange(Sc, dtype=jnp.int32)[None, :]
            valid = valid & ((pos[:, None] - kpos) < W)
    if quant:
        # dequant INSIDE the kernel scope: on TPU the Pallas decode kernel
        # reads int8 + scales from HBM and dequantizes in VMEM
        with jax.named_scope("fusedkernel_decode_attention_dequant"):
            kd = (kc.astype(jnp.float32) * new_entry["k_s"][..., None]).astype(cfg.dtype)
            vd = (vc.astype(jnp.float32) * new_entry["v_s"][..., None]).astype(cfg.dtype)
        o = attn.decode_attention(q, kd, vd, valid)
    else:
        o = attn.decode_attention(q, kc, vc, valid)
    x = x + attn.out_project(lp["attn"], o)
    return x, new_entry


def decode_step(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jax.Array,                 # (B,) int32 — the new token
    cache: Any,                        # pytree from init_cache / prefill
    pos: jax.Array,                    # (B,) int32 — position of the new token
    lengths: jax.Array,                # (B,) int32 — length AFTER this token
    ctx: Optional[Ctx] = None,
    embeds: Optional[jax.Array] = None,
):
    """One decode step. Returns (logits (B, V), hidden (B, d), new_cache, aux)."""
    ctx = ctx or Ctx(cfg=cfg, mode="decode")
    B = tokens.shape[0]
    if embeds is None:
        x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)
    else:
        x = embeds[:, None] if embeds.ndim == 2 else embeds
    if cfg.use_mrope:
        positions = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
    else:
        positions = pos[:, None]
    plan = layer_plan(cfg)
    angle_map = _precompute_angles(cfg, plan, positions)
    angles = lambda kind: angle_map["local" if kind == "local" else "global"]

    shared = params.get("shared")
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = []
    for seg, seg_params, seg_cache in zip(plan, params["segments"], cache):

        def block_fn(carry, xs, _kinds=seg.kinds):
            x, aux = carry
            block_p, block_c = xs
            new_entries = {}
            for j, kind in enumerate(_kinds):
                lp = block_p[f"layer_{j}"]
                entry = block_c[f"layer_{j}"]
                if kind == "ssm":
                    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                    y, st = ssm_mod.ssm_decode_step(lp["ssm"], h, entry, cfg)
                    x = x + y
                    new_entries[f"layer_{j}"] = st
                else:
                    p = shared if kind == "shared_attn" else lp
                    x, ce = _decode_attn_layer(p, x, entry, ctx, kind, pos, lengths,
                                               angles(kind))
                    x, a = _apply_ffn(p, x, ctx, "moe" if kind == "moe" else "mlp")
                    aux = aux + a
                    new_entries[f"layer_{j}"] = ce
            return (x, aux), new_entries

        (x, aux_total), seg_new = jax.lax.scan(
            block_fn, (x, aux_total), (seg_params, seg_cache)
        )
        new_cache.append(seg_new)

    hidden = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
    logits = unembed(hidden, params["embed"], params.get("head"))
    return logits, hidden, new_cache, aux_total


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(
    params, cfg: ModelConfig, tokens, loss_mask=None, ctx: Optional[Ctx] = None,
    embeds=None, positions=None,
):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, _, _, aux = forward(
        params, cfg, tokens=tokens, embeds=embeds, positions=positions, ctx=ctx
    )
    targets = tokens[:, 1:]
    lg = logits[:, :-1]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if loss_mask is not None:
        m = loss_mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(nll)
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux}
