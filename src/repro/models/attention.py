"""Attention: GQA/MQA projections, blocked (flash-style) XLA attention for
train/prefill, and KV-cached decode attention (full cache + sliding ring).

The blocked implementation never materializes an (S, S) score matrix — it
scans KV blocks with an online softmax, which is both the memory-honest
lowering for the roofline analysis and the structural twin of the Pallas
kernel in ``repro.kernels.flash_attention``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.scopes import scoped_kernel_vjp as _scoped_kernel_vjp
from repro.models.layers import ParamSpec, rms_norm
from repro.models.rope import apply_rope

NEG_INF = -1e30


def attn_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    hd = cfg.head_dim
    s = {
        "wq": ParamSpec((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
        s["k_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
    return s


def qkv_project(
    p: Dict[str, jax.Array],
    x: jax.Array,                   # (B, S, d)
    cfg: ModelConfig,
    angles: Optional[jax.Array],    # (B, S, hd//2) or None (no rope: whisper)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    return q, k, v


def out_project(p: Dict[str, jax.Array], o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def blocked_attention(
    q: jax.Array,                 # (B, Sq, H, hd)
    k: jax.Array,                 # (B, Skv, KV, hd)
    v: jax.Array,                 # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,            # absolute position of q[0] (cross/enc: ignored)
    window: int = 0,              # 0 = unwindowed
    kv_valid: Optional[jax.Array] = None,  # (B, Skv) bool
    block_q: int = 512,
    block_kv: int = 512,
    causal_skip: bool = False,    # skip fully-masked KV blocks (perf variant)
) -> jax.Array:
    """Online-softmax blocked attention. Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)

    q, Sq0 = _pad_to(q, 1, block_q)
    k, Skv0 = _pad_to(k, 1, block_kv)
    v, _ = _pad_to(v, 1, block_kv)
    Sqp, Skvp = q.shape[1], k.shape[1]
    nq, nkv = Sqp // block_q, Skvp // block_kv

    q = q.reshape(B, nq, block_q, KV, G, hd)
    kb = jnp.moveaxis(k.reshape(B, nkv, block_kv, KV, hd), 1, 0)   # (nkv, B, bkv, KV, hd)
    vb = jnp.moveaxis(v.reshape(B, nkv, block_kv, KV, hd), 1, 0)
    if kv_valid is not None:
        kv_valid_p, _ = _pad_to(kv_valid, 1, block_kv)
        kvb = jnp.moveaxis(kv_valid_p.reshape(B, nkv, block_kv), 1, 0)  # (nkv, B, bkv)
    else:
        kvb = None

    def q_chunk_attend(qc, qpos, n_blocks, kb, vb, kvb):
        # qc: (B, bq, KV, G, hd); qpos: (bq,)
        def body(carry, blk):
            m, l, acc = carry
            if kvb is None:
                kblk, vblk, kp = blk
                valid = None
            else:
                kblk, vblk, kp, valid = blk
            s = jnp.einsum(
                "bqkgh,bskh->bkgqs", qc, kblk, preferred_element_type=jnp.float32
            ) * scale  # (B, KV, G, bq, bkv) fp32
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask &= kp[None, :] <= qpos[:, None]
            if window:
                mask &= (qpos[:, None] - kp[None, :]) < window
            mask &= (kp < Skv0)[None, :]
            m_full = mask[None, None, None]
            if valid is not None:
                m_full = m_full & valid[:, None, None, None, :]
            s = jnp.where(m_full, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, block_q, hd), jnp.float32)
        # rebuilt here (not closed over) so the custom_vjp bwd re-trace is pure
        kpos = jnp.arange(Skvp, dtype=jnp.int32).reshape(nkv, block_kv)
        xs = (kb[:n_blocks], vb[:n_blocks], kpos[:n_blocks])
        if kvb is not None:
            xs = xs + (kvb[:n_blocks],)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, KV, G, bq, hd)

    def attend_all(q_, kb_, vb_, kvb_):
        outs = []
        for i in range(nq):
            qpos = q_offset + i * block_q + jnp.arange(block_q, dtype=jnp.int32)
            if causal and causal_skip and kv_valid is None and window == 0:
                # only KV blocks whose first position is visible to this chunk
                last_q = q_offset + (i + 1) * block_q - 1
                n_blocks = min(nkv, max(1, -(-min(last_q + 1, Skv0) // block_kv)))
            else:
                n_blocks = nkv
            outs.append(q_chunk_attend(q_[:, i], qpos, n_blocks, kb_, vb_, kvb_))
        return jnp.stack(outs, axis=1)  # (B, nq, KV, G, bq, hd)

    # custom_vjp so BOTH passes carry the fusedkernel scope: on TPU this region
    # is the Pallas flash kernel (fwd) + recompute-based flash bwd kernel; the
    # roofline analyzer treats scoped intermediates as VMEM-resident.
    core = _scoped_kernel_vjp("fusedkernel_flash_attention", attend_all)
    out = core(q, kb, vb, kvb)
    out = jnp.moveaxis(out, -2, 2).reshape(B, Sqp, KV * G, hd)
    return out[:, :Sq0].astype(v.dtype)


def decode_attention(
    q: jax.Array,                # (B, 1, H, hd)
    k_cache: jax.Array,          # (B, Sc, KV, hd)
    v_cache: jax.Array,          # (B, Sc, KV, hd)
    valid: jax.Array,            # (B, Sc) bool — which cache slots participate
) -> jax.Array:
    """Single-token attention against a KV cache. Returns (B, 1, H, hd)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope("fusedkernel_decode_attention"):
        qg = q.reshape(B, KV, G, hd)
        s = jnp.einsum(
            "bkgh,bskh->bkgs", qg, k_cache, preferred_element_type=jnp.float32
        ) * scale
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum(
            "bkgs,bskh->bkgh", (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
            v_cache, preferred_element_type=jnp.float32,
        )
    return o.reshape(B, 1, H, hd).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# KV caches (static-shape, TPU-idiomatic)
# ---------------------------------------------------------------------------


def cache_write_full(k_cache, v_cache, k_new, v_new, pos):
    """Write one token at absolute position ``pos`` (scalar int32)."""
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    return k_cache, v_cache


def cache_write_ring(k_cache, v_cache, k_new, v_new, pos, window: int):
    slot = jnp.mod(pos, window)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
    return k_cache, v_cache


def full_cache_valid(lengths: jax.Array, S: int) -> jax.Array:
    """(B,) current lengths (token count incl. the one just written) -> (B, S)."""
    return jnp.arange(S, dtype=jnp.int32)[None, :] < lengths[:, None]


def ring_cache_valid(lengths: jax.Array, window: int) -> jax.Array:
    return jnp.arange(window, dtype=jnp.int32)[None, :] < jnp.minimum(
        lengths[:, None], window
    )
