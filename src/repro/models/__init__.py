"""Model zoo: dense GQA/MQA decoders, MoE, Mamba2/SSD, Zamba2 hybrid,
Whisper enc-dec, and Qwen2-VL M-RoPE — all as pure-functional JAX models."""
