"""Parameter-spec machinery + elementary layers (norms, MLP, embeddings).

A model is described once as a *spec tree* — nested dicts with
:class:`ParamSpec` leaves (shape + logical sharding axes + initializer).
From the single spec we derive:

* ``init_tree``   — materialized parameters (smoke tests, tiny-LM runs),
* ``shape_tree``  — ShapeDtypeStructs (multi-pod dry-run: zero allocation),
* ``axes_tree``   — logical-axis tuples (resolved to NamedShardings at launch).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed
    scale: Optional[float] = None  # None -> 1/sqrt(fan_in) for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_tree(key: jax.Array, spec_tree: Any, dtype) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(k, s: ParamSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        if s.init == "embed":
            return (jax.random.normal(k, s.shape) * (s.scale or 0.02)).astype(dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        scale = s.scale if s.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, s.shape) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(k, s) for k, s in zip(keys, leaves)]
    )


def shape_tree(spec_tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(dtype)),
        spec_tree,
        is_leaf=_is_spec,
    )


def axes_tree(spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


def stack_specs(spec_tree: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacked (scan) dimension to every spec in the tree."""

    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale)

    return jax.tree_util.tree_map(one, spec_tree, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# elementary ops
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(
        jnp.float32
    )
    return out.astype(dt)


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind in ("silu", "gelu_gated"):
        return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def mlp_spec(d_model: int, d_ff: int, act: str) -> Dict[str, ParamSpec]:
    if act in ("silu", "gelu_gated"):
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "ffn")),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "ffn")),
            "w_down": ParamSpec((d_ff, d_model), ("ffn", "embed")),
        }
    return {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "w_down": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    if "w_gate" in p:
        h = _act(x @ p["w_gate"], act) * (x @ p["w_up"])
    else:
        h = _act(x @ p["w_up"], act)
    return h @ p["w_down"]


def embed_spec(vocab: int, d_model: int) -> ParamSpec:
    return ParamSpec((vocab, d_model), ("vocab", "embed"), init="embed")


def unembed(x: jax.Array, w_embed: jax.Array, w_head: Optional[jax.Array]) -> jax.Array:
    """Project hidden states to vocab logits (fp32 for loss stability)."""
    w = w_embed.T if w_head is None else w_head
    return (x.astype(jnp.float32)) @ (w.astype(jnp.float32))


def sinusoid_positions(n_pos: int, d_model: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embeddings (numpy: baked as constant)."""
    half = d_model // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = np.arange(n_pos)[:, None] * freqs[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)
