"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD form: a `lax.scan` over sequence chunks
carrying the (B, H, P, N) inter-chunk state; within a chunk the computation is
attention-like matmuls (MXU-friendly — this is the part mirrored by the
Pallas kernel in ``repro.kernels.ssd_scan``). Decode is the O(1) recurrence.

Layout: d_inner = H*P, single B/C group shared across heads (G=1).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.scopes import scoped_kernel_vjp
from repro.models.layers import ParamSpec, rms_norm


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(H, P, N, d_conv_channels)."""
    H = cfg.ssm_n_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    return H, P, N, H * P + 2 * N


def ssm_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    H, P, N, d_conv = ssm_dims(cfg)
    d_inner = H * P
    d = cfg.d_model
    return {
        "w_in": ParamSpec((d, 2 * d_inner + 2 * N + H), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv_width, d_conv), ("conv", "ssm_inner"),
                            init="normal", scale=0.5),
        "conv_b": ParamSpec((d_conv,), ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((H,), ("ssm_heads",), init="ones"),
        "norm": ParamSpec((d_inner,), ("ssm_inner",), init="zeros"),
        "w_out": ParamSpec((d_inner, d), ("ssm_inner", "embed")),
    }


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    H, P, N, _ = ssm_dims(cfg)
    d_inner = H * P
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xbc, dt  # (..., d_inner), (..., d_inner + 2N), (..., H)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _discretize(dt_raw, A_log):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))          # (B, S, H)
    A = -jnp.exp(A_log.astype(jnp.float32))                   # (H,)
    return dt, dt * A                                          # dt, a = log-decay


def ssd_chunked(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) fp32 (post-softplus)
    a: jax.Array,    # (B, S, H) fp32 log-decay (dt * A, negative)
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    chunk: int,
    h0: jax.Array = None,  # (B, H, P, N) fp32 or None
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), h_final (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    def to_chunks(t, tail_shape):
        return jnp.moveaxis(t.reshape((B, nc, Q) + tail_shape), 1, 0)

    xc = to_chunks(x, (H, P))
    dtc = to_chunks(dt, (H,))
    ac = to_chunks(a, (H,))
    Bc = to_chunks(Bm, (N,))
    Cc = to_chunks(Cm, (N,))

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    # runs as the Pallas SSD kernel on TPU (repro.kernels.ssd_scan); the
    # roofline analyzer treats intermediates inside this scope as VMEM-resident
    def body(h, inp):
        x_c, dt_c, a_c, B_c, C_c = inp          # (B,Q,H,P), (B,Q,H), ..., (B,Q,N)
        cum = jnp.cumsum(a_c, axis=1)           # (B,Q,H)
        # --- intra-chunk (quadratic within chunk; MXU matmuls) ---
        L = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], L, 0.0)
        CB = jnp.einsum("bqn,bkn->bqk", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))               # (B,Q,Q)
        scores = CB[..., None] * L * dt_c[:, None, :, :]        # (B,Q,Q,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", scores, x_c.astype(jnp.float32))
        # --- contribution of the carried state ---
        y_inter = jnp.einsum(
            "bqn,bqh,bhpn->bqhp", C_c.astype(jnp.float32), jnp.exp(cum), h
        )
        # --- new carried state ---
        cum_last = cum[:, -1:, :]                               # (B,1,H)
        decay_to_end = jnp.exp(cum_last - cum) * dt_c           # (B,Q,H)
        state_new = jnp.einsum(
            "bkn,bkh,bkhp->bhpn", B_c.astype(jnp.float32), decay_to_end,
            x_c.astype(jnp.float32),
        )
        h = jnp.exp(cum_last[:, 0, :])[:, :, None, None] * h + state_new
        return h, (y_intra + y_inter).astype(x.dtype)

    def scanned(xc_, dtc_, ac_, Bc_, Cc_, h0_):
        return jax.lax.scan(body, h0_, (xc_, dtc_, ac_, Bc_, Cc_))

    core = scoped_kernel_vjp("fusedkernel_ssd_scan", scanned)
    h_final, yc = core(xc, dtc, ac, Bc, Cc, h0)
    y = jnp.moveaxis(yc, 0, 1).reshape(B, Sp, H, P)[:, :S]
    return y, h_final


def ssm_apply_train(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Full-sequence Mamba2 layer (train / prefill). x: (B, S, d) -> (B, S, d)."""
    H, P, N, _ = ssm_dims(cfg)
    B, S, d = x.shape
    z, xbc, dt_raw = _split_proj(x @ p["w_in"], cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [H * P, H * P + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt, a = _discretize(dt_raw, p["A_log"])
    y, _ = ssd_chunked(xs, dt, a, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(B, S, H * P)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["w_out"]


def ssm_state_shapes(cfg: ModelConfig, batch: int, dtype) -> Dict[str, tuple]:
    H, P, N, d_conv = ssm_dims(cfg)
    return {
        "h": ((batch, H, P, N), jnp.float32),
        "conv": ((batch, cfg.ssm_conv_width - 1, d_conv), jnp.dtype(dtype)),
    }


def ssm_prefill(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Like train but also returns the decode state (h, conv tail)."""
    H, P, N, _ = ssm_dims(cfg)
    B, S, d = x.shape
    z, xbc_raw, dt_raw = _split_proj(x @ p["w_in"], cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [H * P, H * P + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt, a = _discretize(dt_raw, p["A_log"])
    y, h = ssd_chunked(xs, dt, a, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(B, S, H * P)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    W = cfg.ssm_conv_width
    conv_tail = xbc_raw[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
        xbc_raw, ((0, 0), (W - 1 - S, 0), (0, 0))
    )
    return y @ p["w_out"], {"h": h, "conv": conv_tail.astype(x.dtype)}


def ssm_decode_step(
    p: Dict[str, jax.Array],
    x: jax.Array,                     # (B, 1, d)
    state: Dict[str, jax.Array],      # {"h": (B,H,P,N) f32, "conv": (B,W-1,C)}
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    H, P, N, _ = ssm_dims(cfg)
    B = x.shape[0]
    z, xbc_raw, dt_raw = _split_proj(x @ p["w_in"], cfg)   # (B,1,*)
    window = jnp.concatenate([state["conv"], xbc_raw], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)     # (B,1,C)
    xs, Bm, Cm = jnp.split(xbc, [H * P, H * P + N], axis=-1)
    xs1 = xs.reshape(B, H, P)
    dt, a = _discretize(dt_raw[:, 0], p["A_log"])               # (B,H)
    h = state["h"]
    decay = jnp.exp(a)[:, :, None, None]                        # (B,H,1,1)
    inject = jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xs1.astype(jnp.float32), Bm[:, 0].astype(jnp.float32)
    )
    h = decay * h + inject
    y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs1.astype(jnp.float32)
    y = y.reshape(B, 1, H * P).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    new_state = {"h": h, "conv": window[:, 1:].astype(x.dtype)}
    return y @ p["w_out"], new_state


def ssd_reference(x, dt, a, Bm, Cm):
    """O(S·N·P) sequential oracle for tests: plain recurrence, no chunking."""
    B, S, H, P = x.shape

    def step(h, t):
        xt, dtt, at, Bt, Ct = t
        h = jnp.exp(at)[:, :, None, None] * h + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, Bt
        )
        y = jnp.einsum("bhpn,bn->bhp", h, Ct)
        return h, y

    h0 = jnp.zeros((B, x.shape[2], P, Bm.shape[-1]), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(a, 1, 0),
        jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Cm, 1, 0).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
