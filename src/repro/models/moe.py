"""Mixture-of-Experts FFN with capacity-based dispatch and expert parallelism.

Design notes (TPU adaptation):

* **Capacity dispatch** (GShard/Switch style): tokens are sorted by expert id
  and gathered into a static ``(E_local, capacity, d)`` buffer, so the expert
  matmuls are plain batched einsums. This keeps HLO FLOPs proportional to
  *active* compute (``jax.lax.ragged_dot`` is counted by XLA as dense over all
  experts — a 384× overcount for kimi-k2 — which would poison the roofline).
* **Expert parallelism**: experts are sharded over the ``model`` mesh axis via
  ``shard_map``; activations arrive replicated across that axis (they are
  sharded over ``data`` only), each model column computes its local experts,
  and a single ``psum`` over ``model`` combines — the collective cost is one
  all-reduce of the activation block per MoE layer. The router is replicated
  (its weights are tiny) so no all-gather of logits is needed.
* Overflow beyond capacity is dropped (standard); tests use a capacity factor
  that provably avoids drops so the oracle comparison is exact.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.models.layers import ParamSpec, mlp_spec, mlp_apply

# jax.shard_map landed in 0.6; on older releases it lives in jax.experimental
# with `check_rep` instead of `check_vma` for the replication-check toggle.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
else:  # pragma: no cover - exercised on jax<0.6 images
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_rep": False}


def moe_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    s = {
        "router": ParamSpec((d, E), ("embed", "experts"), scale=0.02),
        # expert weights are too large to replicate over `data` (kimi: 2 TB
        # bf16): stored d-sharded over data ("embed_fsdp") + expert-sharded
        # over model, and explicitly all-gathered inside the shard_map
        "w_gate": ParamSpec((E, d, f), ("experts", "embed_fsdp", "expert_ffn")),
        "w_up": ParamSpec((E, d, f), ("experts", "embed_fsdp", "expert_ffn")),
        "w_down": ParamSpec((E, f, d), ("experts", "expert_ffn", "embed_fsdp")),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_spec(d, cfg.moe_d_ff * cfg.n_shared_experts, "silu")
    return s


def _router(logits32: jax.Array, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (expert_ids (T,k), combine_w (T,k), aux_loss)."""
    T, E = logits32.shape
    probs = jax.nn.softmax(logits32, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)
    combine = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    frac_tokens = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids, E, dtype=jnp.float32), axis=1), axis=0
    )  # (E,) expected assignments per token
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens / k * mean_prob)
    return top_ids, combine, aux


def _capacity(T: int, k: int, E_local: int, factor: float) -> int:
    cap = int(T * k * factor / max(E_local, 1)) + 1
    return max(8, min(cap, T * k))


def _dispatch(local_ids: jax.Array, combine_w: jax.Array, E_local: int,
              capacity: int):
    """Sort-based capacity dispatch bookkeeping (no data movement).

    Returns (gather_tok (E_local, cap) token index per expert slot,
    valid (E_local, cap), weight (E_local, cap))."""
    T, k = local_ids.shape
    Tk = T * k
    flat_ids = local_ids.reshape(Tk)
    order = jnp.argsort(flat_ids)                      # stable; overflow ids last
    tok_of_sorted = order // k                         # token index per sorted row
    w_sorted = combine_w.reshape(Tk)[order]
    counts = jnp.zeros(E_local + 1, jnp.int32).at[flat_ids].add(1)[:E_local]
    offsets = jnp.cumsum(counts) - counts              # (E_local,)
    idx = offsets[:, None] + jnp.arange(capacity, dtype=jnp.int32)[None, :]
    valid = jnp.arange(capacity, dtype=jnp.int32)[None, :] < counts[:, None]
    idx_c = jnp.where(valid, idx, Tk - 1)
    return tok_of_sorted[idx_c], valid, w_sorted[idx_c] * valid


def _expert_mats(xe, w_gate, w_up, w_down):
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xe, w_gate)
    ) * jnp.einsum("ecd,edf->ecf", xe, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _combine(ye, gather_tok, valid, weight, T: int, d: int):
    ye = ye * weight[..., None].astype(ye.dtype)
    out_tok = jnp.where(valid, gather_tok, T)           # invalid -> drop bucket
    out = jnp.zeros((T + 1, d), ye.dtype).at[out_tok.reshape(-1)].add(
        ye.reshape(-1, d))
    return out[:T]


def _expert_ffn_local(
    x: jax.Array,            # (T, d)
    local_ids: jax.Array,    # (T, k) in [0, E_local]; E_local == "not mine"
    combine_w: jax.Array,    # (T, k)
    w_gate: jax.Array,       # (E_local, d, f)
    w_up: jax.Array,
    w_down: jax.Array,       # (E_local, f, d)
    capacity: int,
) -> jax.Array:
    """Capacity-dispatch expert computation on one shard. Returns (T, d)."""
    T = x.shape[0]
    E_local = w_gate.shape[0]
    gather_tok, valid, weight = _dispatch(local_ids, combine_w, E_local, capacity)
    xe = jnp.take(x, gather_tok, axis=0)               # (E_local, cap, d)
    xe = jnp.where(valid[..., None], xe, 0)
    ye = _expert_mats(xe, w_gate, w_up, w_down)
    return _combine(ye, gather_tok, valid, weight, T, x.shape[1])


def moe_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,             # (B, S, d)
    cfg: ModelConfig,
    mesh=None,
    capacity_factor: float = 1.25,
    batch_spec: Optional[P] = None,
    cap_slack: float = 2.0,
    fsdp_mode: str = "gather",    # gather | partial (see Runtime docstring)
) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. Returns (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    k = cfg.n_experts_per_token
    E = cfg.n_experts
    xf = x.reshape(T, d)
    logits32 = (xf.astype(jnp.float32)) @ (p["router"].astype(jnp.float32))
    top_ids, combine, aux = _router(logits32, k)
    cf = capacity_factor if capacity_factor else float(E)  # 0 -> no-drop

    ep = mesh is not None and "model" in mesh.axis_names and mesh.shape["model"] > 1 \
        and E % mesh.shape["model"] == 0
    if not ep:
        cap = _capacity(T, k, E, cf)
        out = _expert_ffn_local(
            xf, top_ids, combine, p["w_gate"], p["w_up"], p["w_down"], cap
        ).reshape(B, S, d)
    else:
        n_shards = mesh.shape["model"]
        E_local = E // n_shards
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        fsdp = tuple(a for a in data_axes if mesh.shape[a] > 1)
        n_data = 1
        for a in data_axes:
            n_data *= int(mesh.shape[a])
        if batch_spec is not None:
            xspec = batch_spec
            tokens_sharded = True
        elif T % max(n_data, 1) == 0 and n_data > 1:
            xspec = P(data_axes, None)
            tokens_sharded = True
        else:
            xspec = P(None, None)  # decode batch=1: tokens replicated
            tokens_sharded = False
        # capacity is per SHARD-LOCAL tokens (computing it on the global T
        # over-allocated the expert matmuls 16x — see EXPERIMENTS.md §Perf)
        T_loc = T // n_data if tokens_sharded else T
        cap = int(_capacity(T_loc, k, E, cf) * cap_slack)
        cap = max(8, min(cap, T_loc * k))
        # expert weights stored d-sharded over the data axes (FSDP) and
        # expert-sharded over model; gathered per layer inside the shard_map
        wspec_up = P("model", fsdp if fsdp else None, None)
        wspec_dn = P("model", None, fsdp if fsdp else None)

        def _local_ids(ids):
            lo = jax.lax.axis_index("model") * E_local
            return jnp.where((ids >= lo) & (ids < lo + E_local), ids - lo, E_local)

        def shard_fn(xf_l, ids_l, cw_l, wg_l, wu_l, wd_l):
            """FSDP mode "gather": all-gather the d-sharded expert weights per
            layer (train-friendly: weight traffic amortized over many tokens)."""
            if fsdp:
                wg_l = jax.lax.all_gather(wg_l, fsdp, axis=1, tiled=True)
                wu_l = jax.lax.all_gather(wu_l, fsdp, axis=1, tiled=True)
                wd_l = jax.lax.all_gather(wd_l, fsdp, axis=2, tiled=True)
            out_l = _expert_ffn_local(xf_l, _local_ids(ids_l), cw_l,
                                      wg_l, wu_l, wd_l, cap)
            return jax.lax.psum(out_l, "model")

        def shard_fn_partial(xf_l, ids_l, cw_l, wg_l, wu_l, wd_l):
            """FSDP mode "partial": weights stay d-sharded; tokens are gathered
            over the data axes (tiny at decode), pre-activations are partial-
            summed. Weight traffic: ZERO; activation traffic ~ O(tokens×f).
            The decode-friendly choice (weights ≫ activations)."""
            n_fsdp = 1
            didx = jnp.zeros((), jnp.int32)
            for a in fsdp:
                n_fsdp *= int(mesh.shape[a])
                didx = didx * int(mesh.shape[a]) + jax.lax.axis_index(a)
            T_l = xf_l.shape[0]
            if fsdp:
                x_g = jax.lax.all_gather(xf_l, fsdp, axis=0, tiled=True)
                ids_g = jax.lax.all_gather(ids_l, fsdp, axis=0, tiled=True)
                cw_g = jax.lax.all_gather(cw_l, fsdp, axis=0, tiled=True)
            else:
                x_g, ids_g, cw_g = xf_l, ids_l, cw_l
            T_g = x_g.shape[0]
            d_l = d // n_fsdp
            x_slice = jax.lax.dynamic_slice_in_dim(x_g, didx * d_l, d_l, 1)
            cap_g = max(8, min(int(_capacity(T_g, k, E, cf) * cap_slack),
                               T_g * k))
            gtok, valid, weight = _dispatch(_local_ids(ids_g), cw_g, E_local,
                                            cap_g)
            xe = jnp.where(valid[..., None], jnp.take(x_slice, gtok, axis=0), 0)
            pre_g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xe, wg_l), fsdp) \
                if fsdp else jnp.einsum("ecd,edf->ecf", xe, wg_l)
            pre_u = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xe, wu_l), fsdp) \
                if fsdp else jnp.einsum("ecd,edf->ecf", xe, wu_l)
            h = jax.nn.silu(pre_g) * pre_u
            ye = jnp.einsum("ecf,efd->ecd", h, wd_l)       # (E_l, cap_g, d_l)
            out_g = _combine(ye, gtok, valid, weight, T_g, d_l)  # (T_g, d_l)
            if fsdp:
                out_full = jax.lax.all_gather(out_g, fsdp, axis=1, tiled=True)
                out_l = jax.lax.dynamic_slice_in_dim(out_full, didx * T_l, T_l, 0)
            else:
                out_l = out_g
            return jax.lax.psum(out_l, "model")

        fn = shard_fn_partial if fsdp_mode == "partial" else shard_fn
        out = _shard_map(
            fn,
            mesh=mesh,
            in_specs=(xspec, xspec, xspec, wspec_up, wspec_up, wspec_dn),
            out_specs=xspec,
            **_SHARD_MAP_NOCHECK,
        )(xf, top_ids, combine, p["w_gate"], p["w_up"], p["w_down"]).reshape(B, S, d)

    if cfg.n_shared_experts and "shared" in p:
        out = out + mlp_apply(p["shared"], x, "silu")
    return out, aux.astype(jnp.float32)
