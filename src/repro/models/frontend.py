"""Modality frontend *stubs* (the one sanctioned carve-out).

Per the assignment, [audio] and [vlm] architectures implement the transformer
backbone only; the mel+conv audio codec and the ViT/SigLIP vision tower are
stubbed — these helpers produce the frame/patch embeddings (and M-RoPE
position streams) with the right shapes/dtypes that a real frontend would.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig


def audio_frame_embeds(key, cfg: ModelConfig, batch: int) -> jax.Array:
    """Stub for Whisper's mel+conv frontend output: (B, encoder_seq, d)."""
    return jax.random.normal(key, (batch, cfg.encoder_seq, cfg.d_model)).astype(
        cfg.dtype
    ) * 0.02


def vlm_embeds(key, cfg: ModelConfig, batch: int, seq: int, n_patches: int = 0):
    """Stub for Qwen2-VL: interleaved patch+text embeddings and M-RoPE ids.

    The first ``n_patches`` positions emulate vision tokens laid out on an
    (h, w) grid (dynamic resolution); the rest are text. Returns
    (embeds (B,S,d), positions (3,B,S)).
    """
    n_patches = n_patches or min(seq // 4, 256)
    emb = jax.random.normal(key, (batch, seq, cfg.d_model)).astype(cfg.dtype) * 0.02
    side = max(int(n_patches ** 0.5), 1)
    t = jnp.concatenate(
        [jnp.zeros(n_patches, jnp.int32),
         jnp.arange(seq - n_patches, dtype=jnp.int32) + 1]
    )
    hh = jnp.concatenate(
        [jnp.arange(n_patches, dtype=jnp.int32) // side, t[n_patches:]]
    )
    ww = jnp.concatenate(
        [jnp.arange(n_patches, dtype=jnp.int32) % side, t[n_patches:]]
    )
    pos = jnp.stack([t, hh, ww])  # (3, S)
    return emb, jnp.broadcast_to(pos[:, None], (3, batch, seq))
