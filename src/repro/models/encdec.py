"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The audio frontend (mel-spectrogram + conv downsampling) is a stub per the
assignment: the encoder consumes precomputed frame embeddings
(B, encoder_seq, d_model). LayerNorm + plain-GELU MLPs, sinusoidal positions
(computed on the fly so arbitrarily long decode positions work), no RoPE.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    ParamSpec,
    embed_spec,
    layer_norm,
    mlp_apply,
    mlp_spec,
    stack_specs,
    unembed,
)


def _ln_spec(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def _ln(p, x, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def _enc_layer_spec(cfg: ModelConfig):
    return {"ln1": _ln_spec(cfg.d_model), "attn": attn.attn_spec(cfg),
            "ln2": _ln_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, "gelu")}


def _dec_layer_spec(cfg: ModelConfig):
    return {"ln1": _ln_spec(cfg.d_model), "self_attn": attn.attn_spec(cfg),
            "ln2": _ln_spec(cfg.d_model), "cross_attn": attn.attn_spec(cfg),
            "ln3": _ln_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, "gelu")}


def whisper_spec(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "encoder": stack_specs(_enc_layer_spec(cfg), cfg.n_encoder_layers),
        "enc_final_ln": _ln_spec(cfg.d_model),
        "decoder": stack_specs(_dec_layer_spec(cfg), cfg.n_layers),
        "dec_final_ln": _ln_spec(cfg.d_model),
    }


def sinusoid_at(positions: jax.Array, d_model: int) -> jax.Array:
    """Sinusoidal embedding rows for arbitrary integer positions (..., d)."""
    half = d_model // 2
    freqs = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array,
           block_q: int = 512, block_kv: int = 512) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings (B, Senc, d)."""
    B, S, d = enc_embeds.shape
    x = enc_embeds + sinusoid_at(jnp.arange(S), d)[None].astype(enc_embeds.dtype)

    def layer(x, lp):
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, None)
        o = attn.blocked_attention(q, k, v, causal=False,
                                   block_q=block_q, block_kv=block_kv)
        x = x + attn.out_project(lp["attn"], o)
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(lp["mlp"], h, "gelu"), None

    x, _ = jax.lax.scan(layer, x, params["encoder"])
    return _ln(params["enc_final_ln"], x, cfg.norm_eps)


def decoder_forward(
    params, cfg: ModelConfig, tokens: jax.Array, enc_out: jax.Array,
    mode: str = "train", block_q: int = 512, block_kv: int = 512,
    attn_valid: Optional[jax.Array] = None, logits_mode: str = "all",
):
    """Causal decoder with cross-attention. Returns (logits, hidden, cache)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = x + sinusoid_at(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)

    def layer(x, lp):
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["self_attn"], h, cfg, None)
        o = attn.blocked_attention(q, k, v, causal=True, kv_valid=attn_valid,
                                   block_q=block_q, block_kv=block_kv)
        x = x + attn.out_project(lp["self_attn"], o)
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        qc, kc, vc = _cross_qkv(lp["cross_attn"], h, enc_out, cfg)
        oc = attn.blocked_attention(qc, kc, vc, causal=False,
                                    block_q=block_q, block_kv=block_kv)
        x = x + attn.out_project(lp["cross_attn"], oc)
        h = _ln(lp["ln3"], x, cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        cache = {"k": k, "v": v, "ck": kc, "cv": vc} if mode == "prefill" else {}
        return x, cache

    x, cache = jax.lax.scan(layer, x, params["decoder"])
    hidden = _ln(params["dec_final_ln"], x, cfg.norm_eps)
    logits = unembed(hidden, params["embed"], None) if logits_mode == "all" else None
    return logits, hidden, (cache if mode == "prefill" else None)


def _cross_qkv(p, h_dec, enc_out, cfg):
    q = jnp.einsum("bsd,dhk->bshk", h_dec, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return q, k, v


def whisper_loss(params, cfg: ModelConfig, tokens, enc_embeds, loss_mask=None):
    enc_out = encode(params, cfg, enc_embeds)
    logits, _, _ = decoder_forward(params, cfg, tokens, enc_out)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if loss_mask is not None:
        m = loss_mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss, {"loss": loss}


def decoder_cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    """Self-attn KV (ring if cfg.attn_window set) + static cross KV."""
    W = cfg.attn_window
    Sc = min(W, cache_len) if W else cache_len
    L = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    ax = ("layers", "batch", "cache_seq", "cache_kv_heads", "head_dim")
    return {
        "k": ParamSpec((L, batch, Sc, kv, hd), ax),
        "v": ParamSpec((L, batch, Sc, kv, hd), ax),
        "ck": ParamSpec((L, batch, cfg.encoder_seq, kv, hd), ax),
        "cv": ParamSpec((L, batch, cfg.encoder_seq, kv, hd), ax),
    }


def decoder_decode_step(
    params, cfg: ModelConfig, tokens: jax.Array, cache: Dict[str, jax.Array],
    pos: jax.Array, lengths: jax.Array,
):
    """One decoder token with cached self-KV + precomputed cross-KV.

    cache: {"k","v": (L,B,Sc,kv,hd), "ck","cv": (L,B,Senc,kv,hd)}
    """
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)
    x = x + sinusoid_at(pos[:, None], cfg.d_model).astype(x.dtype)
    W = cfg.attn_window
    Sc = cache["k"].shape[2]
    ring = bool(W) and Sc == W
    bidx = jnp.arange(B)

    def layer(x, xs):
        lp, kc, vc, ck, cv = xs
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["self_attn"], h, cfg, None)
        if ring:
            slot = jnp.mod(pos, Sc)
            kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype))
            valid = attn.ring_cache_valid(lengths, Sc)
        else:
            kc = kc.at[bidx, pos].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[bidx, pos].set(v[:, 0].astype(vc.dtype))
            valid = attn.full_cache_valid(lengths, Sc)
        o = attn.decode_attention(q, kc, vc, valid)
        x = x + attn.out_project(lp["self_attn"], o)
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        qc = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
        all_valid = jnp.ones((B, ck.shape[1]), bool)
        oc = attn.decode_attention(qc, ck, cv, all_valid)
        x = x + attn.out_project(lp["cross_attn"], oc)
        h = _ln(lp["ln3"], x, cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return x, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        layer, x, (params["decoder"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    hidden = _ln(params["dec_final_ln"], x[:, 0], cfg.norm_eps)
    logits = unembed(hidden, params["embed"], None)
    new_cache = {"k": new_k, "v": new_v, "ck": cache["ck"], "cv": cache["cv"]}
    return logits, hidden, new_cache
