"""Unified model facade: one API across all six architecture families.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure functions
of (params, batch) suitable for jit/pjit:

* ``loss(params, batch, rt)``         — train objective (LM CE + MoE aux)
* ``prefill(params, batch, rt)``      — logits, last-layer hidden states, KV/SSM
                                        cache, aux (serving prefill path)
* ``decode_step(params, batch, cache, rt)`` — one new token vs. the cache
* ``init(key)/param_shapes()/param_axes()/cache_specs(...)`` — materialized or
  shape-only parameters with logical sharding axes.

The ProD predictor head consumes ``hidden`` from prefill/decode — i.e. the
served model's last-layer hidden state, per the paper (§2.4).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import axes_tree, init_tree, shape_tree
from repro.models.transformer import Ctx


@dataclass(frozen=True)
class Runtime:
    """Execution-environment knobs threaded through model calls.

    Performance-iteration knobs (see EXPERIMENTS.md §Perf):
    * ``causal_skip``   — skip fully-masked KV blocks in blocked attention
    * ``moe_cap_slack`` — multiplier on the MoE expert capacity (imbalance headroom)
    * ``moe_fsdp_mode`` — "gather" (all-gather expert weights per layer) or
                          "partial" (d-sliced partial matmuls + activation psum;
                          the decode-friendly choice)
    * ``kv_quant``      — int8 KV cache with per-(token, head) scales (decode)
    * ``seq_shard``     — shard the residual stream's seq dim over `model`
                          between layers (Megatron sequence parallelism)
    """

    mesh: Any = None
    remat: str = "none"
    capacity_factor: float = 1.25
    block_q: int = 512
    block_kv: int = 512
    causal_skip: bool = False
    moe_cap_slack: float = 2.0
    moe_fsdp_mode: str = "gather"
    kv_quant: bool = False
    seq_shard: bool = False

    @staticmethod
    def local() -> "Runtime":
        return Runtime()

    def ctx(self, cfg: ModelConfig, mode: str) -> Ctx:
        return Ctx(
            cfg=cfg, mesh=self.mesh, mode=mode,
            remat=self.remat if mode == "train" else "none",
            block_q=self.block_q, block_kv=self.block_kv,
            causal_skip=self.causal_skip,
            capacity_factor=self.capacity_factor,
            moe_cap_slack=self.moe_cap_slack,
            moe_fsdp_mode=self.moe_fsdp_mode,
            kv_quant=self.kv_quant,
            seq_shard=self.seq_shard,
        )


def last_token_hidden(hidden: jax.Array, lengths: jax.Array) -> jax.Array:
    """φ(x): last-layer hidden state of the last (non-pad) prompt token."""
    idx = jnp.clip(lengths - 1, 0, hidden.shape[1] - 1)
    return hidden[jnp.arange(hidden.shape[0]), idx]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def spec(self):
        if self.cfg.family == "encdec":
            return encdec.whisper_spec(self.cfg)
        return transformer.model_spec(self.cfg)

    def init(self, key, dtype=None):
        return init_tree(key, self.spec(), dtype or self.cfg.dtype)

    def param_shapes(self, dtype=None):
        return shape_tree(self.spec(), dtype or self.cfg.dtype)

    def param_axes(self):
        return axes_tree(self.spec())

    # -- training -----------------------------------------------------------
    def loss(self, params, batch: Dict[str, jax.Array], rt: Runtime):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.whisper_loss(
                params, cfg, batch["tokens"], batch["enc_embeds"],
                loss_mask=batch.get("loss_mask"),
            )
        return transformer.lm_loss(
            params, cfg, batch.get("tokens"), loss_mask=batch.get("loss_mask"),
            ctx=rt.ctx(cfg, "train"), embeds=batch.get("embeds"),
            positions=batch.get("positions"),
        )

    # -- serving ------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jax.Array], rt: Runtime,
                logits_mode: str = "all"):
        """Returns (logits, hidden (B,S,d), cache, aux)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc_out = encdec.encode(params, cfg, batch["enc_embeds"],
                                    block_q=rt.block_q, block_kv=rt.block_kv)
            logits, hidden, cache = encdec.decoder_forward(
                params, cfg, batch["tokens"], enc_out, mode="prefill",
                block_q=rt.block_q, block_kv=rt.block_kv,
                attn_valid=batch.get("attn_valid"), logits_mode=logits_mode,
            )
            return logits, hidden, cache, jnp.zeros((), jnp.float32)
        logits, hidden, cache, aux = transformer.forward(
            params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            positions=batch.get("positions"), attn_valid=batch.get("attn_valid"),
            ctx=rt.ctx(cfg, "prefill"), logits_mode=logits_mode,
        )
        return logits, hidden, cache, aux

    def decode_step(self, params, batch: Dict[str, jax.Array], cache, rt: Runtime):
        """batch: tokens (B,), pos (B,), lengths (B,). Returns (logits, hidden, cache)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.decoder_decode_step(
                params, cfg, batch["tokens"], cache, batch["pos"], batch["lengths"]
            )
        logits, hidden, new_cache, _ = transformer.decode_step(
            params, cfg, batch["tokens"], cache, batch["pos"], batch["lengths"],
            ctx=rt.ctx(cfg, "decode"), embeds=batch.get("embeds"),
        )
        return logits, hidden, new_cache

    # -- caches --------------------------------------------------------------
    def cache_specs(self, batch: int, cache_len: int, kv_quant: bool = False):
        if self.cfg.family == "encdec":
            return encdec.decoder_cache_spec(self.cfg, batch, cache_len)
        return transformer.cache_spec(self.cfg, batch, cache_len,
                                      kv_quant=kv_quant)

    def cache_shapes(self, batch: int, cache_len: int, dtype=None,
                     kv_quant: bool = False):
        dt = dtype or self.cfg.dtype
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, transformer.cache_dtype(s, dt)),
            self.cache_specs(batch, cache_len, kv_quant),
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
        )

    def cache_axes(self, kv_quant: bool = False):
        # axes trees match cache_specs structure
        return jax.tree_util.tree_map(
            lambda s: s.axes, self.cache_specs(1, 2, kv_quant),
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
        )

    def init_cache(self, batch: int, cache_len: int, dtype=None,
                   kv_quant: bool = False):
        dt = dtype or self.cfg.dtype
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, transformer.cache_dtype(s, dt)),
            self.cache_specs(batch, cache_len, kv_quant),
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
