"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head-dim frequency bands into three sections driven by
(temporal, height, width) position streams; for pure text the three streams
coincide and M-RoPE reduces exactly to RoPE (arXiv:2409.12191).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def mrope_section(head_dim: int) -> Tuple[int, int, int]:
    """Frequency-band split (t, h, w); qwen2-vl uses (16, 24, 24) for hd=128."""
    half = head_dim // 2
    t = half - 2 * (3 * half // 8)
    hw = 3 * half // 8
    return (t, hw, hw)


def rope_angles(
    positions: jax.Array,  # (B, S) int32 or (3, B, S) for M-RoPE
    head_dim: int,
    theta: float,
    use_mrope: bool = False,
) -> jax.Array:
    """Return rotation angles of shape (B, S, head_dim//2)."""
    freqs = rope_freqs(head_dim, theta)  # (half,)
    if not use_mrope:
        if positions.ndim == 3:  # text-only M-RoPE degenerates to stream 0
            positions = positions[0]
        return positions[..., None].astype(jnp.float32) * freqs
    assert positions.ndim == 3 and positions.shape[0] == 3, positions.shape
    t, h, w = mrope_section(head_dim)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (3, B, S, half)
    return jnp.concatenate(
        [ang[0, ..., :t], ang[1, ..., t : t + h], ang[2, ..., t + h :]], axis=-1
    )


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, head_dim); angles: (B, S, head_dim//2)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


def positions_from_tokens(batch: int, seq: int, offset=0) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def text_mrope_positions(batch: int, seq: int, offset=0) -> jax.Array:
    p = positions_from_tokens(batch, seq, offset)
    return jnp.broadcast_to(p[None], (3, batch, seq))
