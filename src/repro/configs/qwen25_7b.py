"""Qwen2.5-7B — one of the paper's served models (Section 3.1) [arXiv:2412.15115]."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-7b",
        family="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        rope_theta=1e6,
        citation="arXiv:2412.15115",
    )
