"""Llama-3-8B — one of the paper's served models (Section 3.1) [Meta AI 2024]."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=5e5,
        citation="Meta AI 2024 (https://ai.meta.com/llama/)",
    )
