"""Qwen2-VL-2B language backbone [arXiv:2409.12191].

M-RoPE (3 position streams: temporal/height/width) + dynamic resolution.
The ViT vision encoder + projector is a stub — ``input_specs`` provides
interleaved text/patch embeddings plus M-RoPE position ids.
"""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        use_mrope=True,
        rope_theta=1e6,
        tie_embeddings=True,
        citation="arXiv:2409.12191",
    )
