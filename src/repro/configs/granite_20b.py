"""Granite-20B (code) — llama-arch with MQA (kv=1) [arXiv:2405.04324]."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        citation="arXiv:2405.04324",
    )
