"""Mamba2-130M — SSD (state-space duality), attention-free [arXiv:2405.21060]."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,       # attention-free; unused
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,  # d_inner = 2*d_model = 1536 -> 24 SSD heads
        tie_embeddings=True,
        citation="arXiv:2405.21060",
    )
