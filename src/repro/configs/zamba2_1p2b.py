"""Zamba2-1.2B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

38 Mamba2 layers; a single weight-shared attention+MLP block is applied every
``attn_every`` SSM layers (Zamba2's shared-block design).
"""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        attn_every=6,
        attn_window=8192,  # shared attn block uses a KV ring at long context
        citation="arXiv:2411.15242",
    )
