"""Whisper-large-v3 transformer backbone [arXiv:2212.04356].

Encoder-decoder; the mel-spectrogram + conv feature extractor frontend is a
stub per the assignment — ``input_specs`` provides precomputed frame
embeddings of shape (batch, encoder_seq, d_model).
"""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,
        n_encoder_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        encoder_seq=1500,
        act="gelu",
        norm_eps=1e-5,
        predictor_bin_max=448.0,  # whisper's decode budget
        citation="arXiv:2212.04356",
    )
