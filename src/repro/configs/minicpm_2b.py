"""MiniCPM-2B [arXiv:2404.06395] — llama-like dense arch trained with the WSD
(warmup-stable-decay) schedule; the schedule lives in ``repro.training.optim``."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab_size=122753,
        tie_embeddings=True,
        citation="arXiv:2404.06395",
    )
