"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2].

GQA kv=8, per-expert d_ff=2048, one shared expert (K2 paper table).
"""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,
        moe_d_ff=2048,
        vocab_size=163840,
        n_experts=384,
        n_experts_per_token=8,
        n_shared_experts=1,
        citation="arXiv:2501.kimi2",
    )
