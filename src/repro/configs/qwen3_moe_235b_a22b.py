"""Qwen3-MoE 235B-A22B — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B]."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,
        moe_d_ff=1536,
        vocab_size=151936,
        n_experts=128,
        n_experts_per_token=8,
        qk_norm=True,
        citation="hf:Qwen/Qwen3-30B-A3B",
    )
