"""Tiny decoder LM used for CPU end-to-end runs (real generation + hidden-state
harvesting for the ProD pipeline). Not part of the assigned pool."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="tiny-lm",
        family="dense",
        n_layers=4,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=384,
        vocab_size=512,
        tie_embeddings=True,
        predictor_bins=32,
        predictor_bin_max=256.0,
        citation="(internal tiny model)",
    )
