"""Gemma3-27B — 5:1 local:global attention pattern, 128k context
[hf:google/gemma-3-1b-pt]."""

from repro.common.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab_size=262144,
        local_global_ratio=5,
        attn_window=1024,
        rope_theta=1e6,
        rope_theta_local=10000.0,
        qk_norm=True,
        tie_embeddings=True,
        act="gelu_gated",
        citation="hf:google/gemma-3-1b-pt",
    )
