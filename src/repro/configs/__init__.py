"""Architecture registry: 10 assigned architectures + the paper's served models.

Every config cites its source in ``citation`` and is selectable via
``--arch <id>`` in the launch scripts.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.config import ModelConfig

_MODULES: Dict[str, str] = {
    # assigned pool (exact values from the assignment block)
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "yi-34b": "repro.configs.yi_34b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "granite-20b": "repro.configs.granite_20b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    # the paper's own served models (Section 3.1)
    "qwen2.5-7b": "repro.configs.qwen25_7b",
    "llama3-8b": "repro.configs.llama3_8b",
    # tiny models for CPU end-to-end runs
    "tiny-lm": "repro.configs.tiny_lm",
}

ASSIGNED_ARCHS: List[str] = [
    "whisper-large-v3",
    "qwen2-vl-2b",
    "minicpm-2b",
    "kimi-k2-1t-a32b",
    "qwen3-moe-235b-a22b",
    "yi-34b",
    "zamba2-1.2b",
    "gemma3-27b",
    "granite-20b",
    "mamba2-130m",
]


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; options: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.make_config()
