"""Generic training loop: pjit-able train step with grad clipping, gradient
accumulation (microbatching), schedules, and periodic checkpointing."""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig
from repro.models.model_zoo import Model, Runtime
from repro.training.optim import Optimizer, clip_by_global_norm, make_optimizer


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state, "step": self.step}


def init_state(model: Model, key: jax.Array, tcfg: TrainConfig) -> TrainState:
    params = model.init(key)
    opt = make_optimizer(tcfg)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.float32))


def make_train_step(
    model: Model, tcfg: TrainConfig, rt: Runtime
) -> Callable[[TrainState, Dict[str, jax.Array]], Any]:
    """Returns train_step(state, batch) -> (state, metrics). Pure — jit/pjit at
    the call site (the launcher attaches shardings)."""
    import dataclasses

    opt = make_optimizer(tcfg)
    rt = dataclasses.replace(rt, remat=tcfg.remat if tcfg.remat != "none"
                             else "none")

    def loss_fn(params, batch):
        return model.loss(params, batch, rt)

    def train_step(state_tree, batch):
        params = state_tree["params"]
        n_micro = max(tcfg.microbatch, 1)
        if n_micro > 1:
            B = batch["tokens"].shape[0]
            mb = B // n_micro

            def micro(i, acc):
                g_acc, l_acc, w_acc = acc
                sub = {k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, 0)
                       for k, v in batch.items()}
                # microbatches with a loss_mask carry different numbers of
                # supervised tokens; the per-microbatch loss is a *mean* over
                # those tokens, so equal-weight accumulation diverges from the
                # full-batch loss. Weight by supervised-token count (the [1:]
                # shift matches lm_loss's next-token targets) to make
                # mean-of-means equal the global mean, for loss AND grads.
                if "loss_mask" in sub:
                    w = jnp.maximum(
                        jnp.sum(sub["loss_mask"][:, 1:].astype(jnp.float32)), 1.0)
                else:
                    w = jnp.asarray(float(mb), jnp.float32)
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, sub)
                g_acc = jax.tree_util.tree_map(lambda a, b: a + w * b, g_acc, g)
                return (g_acc, l_acc + w * l, w_acc + w)

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss, wsum = jax.lax.fori_loop(
                0, n_micro, micro, (g0, 0.0, 0.0))
            grads = jax.tree_util.tree_map(lambda g: g / wsum, grads)
            loss = loss / wsum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt = opt.update(grads, state_tree["opt_state"], params,
                                         state_tree["step"])
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        out_metrics.update({k: v for k, v in (metrics or {}).items()})
        return (
            {"params": new_params, "opt_state": new_opt,
             "step": state_tree["step"] + 1},
            out_metrics,
        )

    return train_step


def train_loop(
    model: Model,
    tcfg: TrainConfig,
    data_iter: Iterator[Dict[str, jax.Array]],
    n_steps: int,
    rt: Optional[Runtime] = None,
    log_every: int = 20,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    verbose: bool = True,
) -> TrainState:
    rt = rt or Runtime.local()
    state = init_state(model, jax.random.PRNGKey(tcfg.seed), tcfg)
    step_fn = jax.jit(make_train_step(model, tcfg, rt))
    tree = state.tree()
    t0 = time.time()
    for i in range(n_steps):
        batch = next(data_iter)
        tree, metrics = step_fn(tree, batch)
        if verbose and (i % log_every == 0 or i == n_steps - 1):
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            from repro.training.checkpoint import save_checkpoint
            save_checkpoint(ckpt_dir, tree, step=i + 1)
    return TrainState(params=tree["params"], opt_state=tree["opt_state"],
                      step=tree["step"])
