"""Checkpointing: flat-path npz arrays + JSON manifest (no orbax dependency).

Works for any pytree of arrays (params, optimizer state, predictor heads).
Multi-host note: each process saves only addressable shards in a real
deployment; on the CPU container this is the single-process path.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, tree: Any, step: int = 0, name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    np.savez(path, **flat)
    manifest = {
        "step": step,
        "n_arrays": len(flat),
        "total_bytes": int(sum(v.nbytes for v in flat.values())),
        "keys": sorted(flat),
    }
    with open(os.path.join(directory, f"{name}_{step:08d}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_checkpoint(directory: str, name: str = "ckpt") -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = sorted(
        f for f in os.listdir(directory)
        if f.startswith(name + "_") and f.endswith(".npz")
    )
    return os.path.join(directory, cands[-1]) if cands else None


def restore_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (validates every leaf)."""
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
