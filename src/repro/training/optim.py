"""Pure-JAX optimizers and LR schedules (no optax dependency).

* AdamW — standard decoupled weight decay.
* Adafactor — factored second moment (rank-1 row/col stats for matrices);
  the memory-frugal choice that lets the 1T MoE train config fit (see
  EXPERIMENTS.md §Dry-run).
* Schedules — cosine, constant, and **WSD** (warmup–stable–decay), the
  MiniCPM schedule [arXiv:2404.06395].
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def lr_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    peak = cfg.lr
    warm = max(cfg.warmup_steps, 1)

    def cosine(step):
        frac = jnp.clip((step - warm) / max(cfg.decay_steps - warm, 1), 0.0, 1.0)
        return peak * jnp.where(
            step < warm, step / warm, 0.5 * (1 + jnp.cos(jnp.pi * frac))
        )

    def constant(step):
        return peak * jnp.minimum(step / warm, 1.0)

    def wsd(step):
        """Warmup -> stable plateau -> exponential-ish decay (MiniCPM)."""
        stable_end = warm + cfg.stable_steps
        decay_len = max(cfg.decay_steps - stable_end, 1)
        frac = jnp.clip((step - stable_end) / decay_len, 0.0, 1.0)
        return peak * jnp.where(
            step < warm,
            step / warm,
            jnp.where(step < stable_end, 1.0, 0.5 ** (frac * 10.0)),
        )

    return {"cosine": cosine, "constant": constant, "wsd": wsd}[cfg.schedule]


# ---------------------------------------------------------------------------
# gradient utilities
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def adamw(cfg: TrainConfig) -> Optimizer:
    sched = lr_schedule(cfg)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, 1e-8, cfg.weight_decay

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params, step):
        lr = sched(step + 1)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            step_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            decay = wd * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            newp = p.astype(jnp.float32) - lr * (step_ + decay)
            return newp.astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(cfg: TrainConfig) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern). For rank>=2 leaves
    keeps row/col statistics only — the memory saver for the 1T configs."""
    sched = lr_schedule(cfg)
    eps = 1e-30
    clip_thresh = 1.0

    def init(params):
        def zst(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree_util.tree_map(zst, params)

    def update(grads, state, params, step):
        lr = sched(step + 1)
        beta2 = 1.0 - (step + 1.0) ** -0.8

        def upd(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                v = vr[..., None] * vc[..., None, :] / denom[..., None]
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                new_st = {"v": v}
            u = g32 / jnp.sqrt(v + eps)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_thresh)
            newp = p.astype(jnp.float32) - lr * u
            return newp.astype(p.dtype), new_st

        is_st = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_s = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return new_p, new_s

    return Optimizer(init, update)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor}[cfg.optimizer](cfg)


def sgd_simple(lr: float) -> Optimizer:
    """Plain SGD (used by tiny property tests)."""

    def init(params):
        return {}

    def update(grads, state, params, step):
        new_p = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return new_p, state

    return Optimizer(init, update)
