"""Training substrate: optimizers, LR schedules, trainer loops, checkpoints."""
