"""Train a language model on the heavy-tailed toy corpus with the full
substrate (pipeline → optimizer → schedule → checkpointing). On CPU this runs
the tiny config; pass --arch/--steps to scale on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --ckpt-dir /tmp/ck
"""

import argparse

import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_config, list_archs
from repro.data.pipeline import batch_iterator, make_lm_dataset
from repro.models.model_zoo import Runtime, build_model
from repro.training.trainer import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.arch != "tiny-lm":
        cfg = cfg.reduced()
    cfg = cfg.with_overrides(dtype="float32")
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_count():,} (analytic)")
    tcfg = TrainConfig(lr=args.lr, schedule=args.schedule,
                       warmup_steps=max(args.steps // 20, 2),
                       decay_steps=args.steps,
                       stable_steps=args.steps // 2 if args.schedule == "wsd" else 0,
                       seed=args.seed)
    ds = make_lm_dataset(4096, args.seq, seed=args.seed)
    ds.tokens = np.minimum(ds.tokens, cfg.vocab_size - 1)
    it = batch_iterator(ds, args.batch, seed=args.seed)
    state = train_loop(model, tcfg, it, args.steps, rt=Runtime.local(),
                       ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.steps // 2 if args.ckpt_dir else 0)
    print(f"finished at step {int(state.step)}")


if __name__ == "__main__":
    main()
