"""Quickstart: train ProD-M and ProD-D on one calibrated scenario and compare
against the baselines — the paper's Table-1 experiment in miniature.

    PYTHONPATH=src python examples/quickstart.py [--model qwen] [--scenario math]
"""

import argparse

import jax
import numpy as np

from repro.common.config import PredictorConfig
from repro.core.baselines import METHODS, run_method
from repro.core.metrics import noise_radius
from repro.data import make_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="qwen", choices=["qwen", "llama"])
    ap.add_argument("--scenario", default="math",
                    choices=["math", "coding", "longseq", "chat"])
    ap.add_argument("--n-train", type=int, default=800)
    ap.add_argument("--n-test", type=int, default=400)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"scenario: {args.model}/{args.scenario} "
          f"(calibrated to the paper's noise statistics)")
    data = make_scenario(args.model, args.scenario, n_train=args.n_train,
                         n_test=args.n_test, seed=args.seed)
    bin_max = float(np.quantile(data.len_train, 0.999) * 1.3)
    pcfg = PredictorConfig(n_bins=64, bin_max=bin_max, epochs=args.epochs)

    print(f"{'method':18s} {'test MAE':>10s}")
    key = jax.random.PRNGKey(args.seed)
    for i, method in enumerate(METHODS):
        res = run_method(jax.random.fold_in(key, i), data, method, pcfg)
        extra = f"  {res.selected}" if res.selected else ""
        print(f"{method:18s} {res.test_mae:10.2f}{extra}")
    print(f"{'noise radius':18s} {noise_radius(data.len_test):10.2f}  "
          f"(decoding-stochasticity floor)")


if __name__ == "__main__":
    main()
