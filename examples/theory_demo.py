"""Theorem 1 / Lemma 3 walk-through on the linear surrogate (paper App. B).

    PYTHONPATH=src python examples/theory_demo.py
"""

import numpy as np

from repro.core import theory as TH
from repro.data.synthetic import surrogate_linear_data


def main():
    N, d, eps, v, S, lam, delta = 1000, 8, 0.5, 1.0, 1.0, 1.0, 0.05
    print(f"surrogate: N={N} d={d} eps={eps} v={v} (student-t noise, "
          f"E|eta|^{{1+eps}} <= v)")

    print("\n-- Lemma 3: median-of-r keeps the (1+eps)-moment within 2v --")
    for r in (4, 16, 64):
        base, med = TH.lemma3_moment(
            lambda rng, s: rng.standard_t(1 + 2 * eps, size=s), r=r, eps=eps)
        print(f"  r={r:3d}  E|X|^1.5={base:.3f}  E|med_r|^1.5={med:.4f}  "
              f"(bound {2*base:.3f})")

    print("\n-- failure term 4N exp(-r/8) and the r* threshold --")
    for r in (8, 16, 32, 64, TH.r_required(N, delta)):
        print(f"  r={r:3d}  4N·e^(-r/8) = {TH.failure_prob(N, r):.4g}")
    print(f"  r* = 8 log(4N/δ) = {TH.r_required(N, delta)} "
          f"(makes the term ≤ δ = {delta})")

    print("\n-- estimation error: single-draw vs median-of-16 labels --")
    errs = {"single": [], "median16": []}
    for t in range(10):
        phi, eta, theta = surrogate_linear_data(N, d, eps, v, r=16, seed=t)
        y = phi @ theta
        errs["single"].append(
            np.linalg.norm(TH.ridge_fit(phi, y + eta[:, 0], lam).theta - theta))
        errs["median16"].append(np.linalg.norm(
            TH.ridge_fit(phi, y + np.median(eta, 1), lam).theta - theta))
    for k, v_ in errs.items():
        print(f"  ||theta-hat − theta*||  ({k:9s}) = "
              f"{np.mean(v_):.4f} ± {np.std(v_):.4f}")

    print("\n-- Theorem 1 pointwise bound coverage --")
    r_star = TH.r_required(N, delta)
    phi, eta, theta = surrogate_linear_data(N, d, eps, v, r=r_star, seed=99)
    fit = TH.ridge_fit(phi, phi @ theta + np.median(eta, 1), lam)
    beta = TH.theorem1_beta(N, d, v, eps, delta, lam, S)
    cov = TH.empirical_coverage(fit, phi, phi @ theta, beta)
    print(f"  beta_N = {beta:.2f}; coverage of "
          f"|phi^T(theta*−theta-hat)| ≤ beta_N ||phi||_V^-1 : {cov:.3f} "
          f"(Thm 1 guarantees ≥ {1-2*delta})")


if __name__ == "__main__":
    main()
