"""End-to-end serving driver (deliverable b): serve a small model with batched
requests, with the ProD predictor driving scheduling + KV reservation.

Pipeline (all real, no stubs):
  1. train the tiny LM on the heavy-tailed toy corpus (a few hundred steps);
  2. collect r repeated generations per training prompt at temperature 0.8
     (the paper's data-collection protocol) and harvest real last-layer
     hidden states from prefill;
  3. build ProD-D targets and train the head;
  4. serve a fresh batched workload through the continuous-batching engine,
     comparing FCFS/max-reserve vs ProD-driven SJF + quantile reservation;
  5. replay the same workload across a 2-replica cluster, comparing the
     load-blind round-robin/max-reserve router against the ProD-aware
     predicted-shortest-queue router with quantile KV reservation;
  6. put the trained head IN the dispatch loop: a PredictorService batches
     the head over arrival windows (one jitted fused call per window) and
     the cluster orders its queues by EDF / least-laxity on the predicted
     q0.9 remaining work;
  7. close the loop: drift the workload mid-stream (outputs grow 1.5x while
     features stay put) and serve it with an OnlineAdapter — adaptive
     conformal reservation calibration + warm-start head refresh + SLO-aware
     admission — against the frozen static head;
  8. page the KV cache: replay an SRTF-preemptive, prefill-expensive engine
     under preempt_mode="recompute" (a preempted victim re-reserves and
     re-prefills from scratch) vs "keep" (it holds its filled pages and
     resumes with only the delta), showing the recompute ticks saved;
  9. share the system prompt: replay the workload with every request
     carrying the same 24-token prefix, private copies vs ref-counted
     shared pages (kv_amplification, prefill ticks skipped on cache hits).

    PYTHONPATH=src python examples/serve_with_prod.py [--train-steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PredictorConfig, TrainConfig
from repro.configs import get_config
from repro.core import bins as B
from repro.core import targets as T
from repro.core.metrics import mae, noise_radius
from repro.core.predictor import train_predictor
from repro.data.pipeline import batch_iterator, make_lm_dataset
from repro.data.tokenizer import N_TOPICS, ToyTokenizer
from repro.models.model_zoo import Runtime, build_model
from repro.serving.adaptation import (AdaptationConfig, AdmissionController,
                                      OnlineAdapter, coverage_of)
from repro.serving.cluster import Cluster
from repro.serving.engine import RealEngine, ReplicaSpec, SimEngine
from repro.serving.predictor import PredictorService
from repro.serving.request import Request
from repro.serving.scheduler import Policy
from repro.training.trainer import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--n-prompts", type=int, default=64)
    ap.add_argument("--r", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--n-serve", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # -- 1. train the served LM ---------------------------------------------
    cfg = get_config("tiny-lm").with_overrides(dtype="float32")
    model = build_model(cfg)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=10, decay_steps=args.train_steps,
                       seed=args.seed)
    ds = make_lm_dataset(2048, 96, seed=args.seed)
    print(f"[1/9] training tiny-lm for {args.train_steps} steps ...")
    state = train_loop(model, tcfg, batch_iterator(ds, 16, seed=args.seed),
                       args.train_steps, rt=Runtime.local(), log_every=100)

    # -- 2. repeated-sampling data collection --------------------------------
    print(f"[2/9] collecting {args.r} generations x {args.n_prompts} prompts ...")
    eng = RealEngine(model, state.params, max_new=args.max_new, temperature=0.8)
    rng = np.random.default_rng(args.seed)
    tok = ToyTokenizer()
    prompts = np.zeros((args.n_prompts, 6), np.int32)
    for i in range(args.n_prompts):
        prompts[i] = tok.prompt(rng, int(rng.integers(0, N_TOPICS)), n_style=4)
    plens = np.full(args.n_prompts, 6)
    t0 = time.time()
    lens, phi = eng.repeated_sampling(prompts, plens, r=args.r, seed=args.seed)
    nr = noise_radius(jnp.asarray(lens))
    print(f"      lengths: median={np.median(lens):.0f} "
          f"max/med={np.max(lens)/max(np.median(lens),1):.2f} "
          f"noise radius={nr:.2f}  ({time.time()-t0:.0f}s)")

    # -- 3. train the ProD-D head on REAL hidden states ----------------------
    print("[3/9] training ProD-D head on the served model's hidden states ...")
    pcfg = PredictorConfig(n_bins=24, bin_max=float(lens.max() + 8), epochs=40,
                           batch_size=32)
    edges = B.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = T.dist_target(jnp.asarray(lens, jnp.float32), edges)
    pred = train_predictor(jax.random.PRNGKey(args.seed + 1), jnp.asarray(phi),
                           tgt, pcfg, edges)
    est = pred.predict(jnp.asarray(phi))
    print(f"      in-sample MAE vs prompt medians: "
          f"{mae(est, jnp.asarray(np.median(lens, axis=1))):.2f} "
          f"(noise radius {nr:.2f})")

    # -- 4. serve a fresh workload with ProD scheduling ----------------------
    print(f"[4/9] serving {args.n_serve} batched requests ...")
    arrivals = np.cumsum(rng.exponential(1.5, args.n_serve))
    fresh = rng.integers(0, args.n_prompts, args.n_serve)
    reqs = []
    for i, (j, t) in enumerate(zip(fresh, arrivals)):
        draw = int(lens[j, rng.integers(0, args.r)])  # a fresh-ish realization
        reqs.append(Request(rid=i, arrival=float(t), prompt_len=6,
                            true_len=draw, phi=phi[j]))
    for pol in (Policy("fcfs", "max", max_seq_len=args.max_new),
                Policy("sjf_pred", "quantile", quantile=0.9,
                       max_seq_len=args.max_new)):
        st = SimEngine(max_slots=8, kv_budget=4 * (6 + args.max_new),
                       policy=pol, predictor=pred).run(reqs)
        print(f"      {st.policy:20s} mean_lat={st.mean_latency:7.1f} "
              f"p90={st.p90_latency:7.1f} waste={st.kv_waste_ratio:.3f} "
              f"thr={st.throughput:.2f}")

    # -- 5. heterogeneous cluster replay with the trained ProD head ----------
    # a fast large replica next to a slow small one, per-request SLOs, and
    # periodic ProD-aware work stealing: the full prediction-aware stack
    print("[5/9] replaying across a heterogeneous 2-replica cluster "
          "(speed 2x+1x, SLOs, work stealing) ...")
    specs = (ReplicaSpec(4, 2 * (6 + args.max_new), speed=2,
                         prefill_tokens_per_step=8),
             ReplicaSpec(2, 6 + args.max_new, speed=1,
                         prefill_tokens_per_step=4))
    # tiered SLOs: alternate interactive (tight) / standard / batch (loose)
    # classes, so deadline-aware orderings have real urgency differences
    for i, r in enumerate(reqs):
        r.deadline = r.arrival + (2.0 + 2.0 * (i % 3)) * args.max_new
    for router, pol, reb in (
            ("round_robin", Policy("fcfs", "max", max_seq_len=args.max_new),
             0),
            ("psq", Policy("fcfs", "quantile", quantile=0.9,
                           max_seq_len=args.max_new), 25)):
        cl = Cluster(specs, pol, router=router, predictor=pred,
                     rebalance_every=reb, steal="quantile")
        st = cl.run(reqs)
        label = f"steal@{reb}" if reb else "no-steal"
        print(f"      {st.router:12s}+{st.policy:14s} {label:9s} "
              f"p50={st.p50_latency:7.1f} p99={st.p99_latency:7.1f} "
              f"viol={st.slo_violations} t/o={st.timed_out} "
              f"goodput={st.goodput:.2f} stolen={st.stolen} "
              f"balance={st.balance:.2f}")

    # -- 6. predictor service in the dispatch loop ---------------------------
    # the SAME trained head, now served through the batched jitted
    # PredictorService, driving deadline-aware queue orderings
    print("[6/9] predictor-in-the-loop: batched dispatch-time inference + "
          "deadline-aware ordering ...")
    for order in ("fcfs", "edf", "laxity"):
        svc = PredictorService(pred, window=8.0)
        pol = Policy(order, "quantile", quantile=0.9,
                     max_seq_len=args.max_new)
        st = Cluster(specs, pol, router="psq", predictor=svc,
                     rebalance_every=25, steal="quantile").run(reqs)
        srow = svc.stats.row()
        print(f"      order={order:7s} p50={st.p50_latency:7.1f} "
              f"p99={st.p99_latency:7.1f} viol={st.slo_violations} "
              f"t/o={st.timed_out} goodput={st.goodput:.2f} "
              f"[{srow['batches']} fused batches, mean "
              f"{srow['mean_batch']:.1f} reqs, hit rate {srow['hit_rate']:.2f}]")

    # -- 7. online adaptation under drift ------------------------------------
    # mid-stream regime change: outputs grow 1.5x while the hidden-state
    # features stay put, so the frozen head silently under-reserves. The
    # OnlineAdapter steers the reservation quantile to its coverage target
    # (ACI), warm-start re-fits the head on observed completions, and the
    # AdmissionController rejects SLO-infeasible requests at enqueue. A
    # longer workload (3x the serve set, switch after the first third) gives
    # the feedback loop room to act; coverage is scored on the settled last
    # third.
    print("[7/9] online adaptation: mid-stream 1.5x output drift, static vs "
          "adaptive-conformal + refresh ...")
    n_ad = 3 * args.n_serve
    arr2 = np.cumsum(rng.exponential(1.5, n_ad))
    picks = rng.integers(0, args.n_prompts, n_ad)
    t_switch = float(arr2[n_ad // 3])
    t_tail = float(arr2[2 * n_ad // 3])
    drift_reqs = []
    for i, (j, t) in enumerate(zip(picks, arr2)):
        draw = int(lens[j, rng.integers(0, args.r)])
        if t >= t_switch:
            draw = int(min(args.max_new, round(1.5 * draw)))
        drift_reqs.append(Request(
            rid=i, arrival=float(t), prompt_len=6, true_len=draw, phi=phi[j],
            deadline=float(t) + (2.0 + 2.0 * (i % 3)) * args.max_new))
    for label, gamma, refresh in (("static", 0.0, False),
                                  ("conformal+refresh", 0.05, True)):
        acfg = AdaptationConfig(
            target_coverage=0.9, gamma=gamma, window=64, every=8,
            refresh_every=0.25 * t_switch if refresh else 0.0,
            refresh_min_samples=24, refresh_epochs=30, buffer_size=128)
        adapter = OnlineAdapter(PredictorService(pred, window=8.0), acfg)
        pol = Policy("fcfs", "quantile", quantile=0.9,
                     max_seq_len=args.max_new)
        cl = Cluster(specs, pol, router="psq", predictor=adapter,
                     admission=AdmissionController())
        st = cl.run(drift_reqs)
        cov = coverage_of([r for e in cl.engines for r in e.done],
                          since=t_tail)
        print(f"      {label:18s} settled post-drift coverage={cov:.2f} "
              f"(target 0.90) p99={st.p99_latency:7.1f} "
              f"viol={st.slo_violations} t/o={st.timed_out} "
              f"rejected={st.rejected} refits={st.refreshes} "
              f"q_eff={adapter.q_eff:.3f}")
    # -- 8. paged KV + keep-pages preemption ---------------------------------
    # an SRTF-preemptive single replica with an expensive prefill: under
    # "recompute", every preempted victim re-pays ceil((prompt+progress)/4)
    # prefill ticks on resume; under "keep" it holds the pages it filled
    # (shown by held_peak) and resumes with only the delta reservation
    print("[8/9] paged KV: recompute vs keep-pages preemption "
          "(page_size=4, prefill 4 tok/tick) ...")
    for mode in ("recompute", "keep"):
        pol = Policy("srtf_pred", "quantile", quantile=0.9,
                     max_seq_len=args.max_new, preempt=True,
                     preempt_factor=1.2, preempt_mode=mode)
        spec = ReplicaSpec(2, 4 * (6 + args.max_new) // 4 * 4, speed=2,
                           prefill_tokens_per_step=4, page_size=4)
        st = SimEngine(policy=pol, predictor=pred, spec=spec).run(reqs)
        print(f"      {mode:10s} p50={st.p50_latency:7.1f} "
              f"p99={st.p99_latency:7.1f} preempt={st.preemptions} "
              f"recompute_ticks={st.recompute_ticks} "
              f"held_peak={st.held_peak} occ={st.occupancy:.3f} "
              f"frag={st.frag_ratio:.4f}")
    # -- 9. shared-prefix KV pages -------------------------------------------
    # every request now carries a 24-token system prompt as a shared prefix:
    # with share_prefixes=True one physical copy backs all concurrent
    # requests (ref-counted; kv_amplification > 1) and later admits skip
    # re-prefilling the covered tokens (prefill_saved_ticks)
    print("[9/9] shared system prompt: private copies vs ref-counted "
          "prefix pages ...")
    import dataclasses
    sys_len = 24
    shared_reqs = [dataclasses.replace(r, prompt_len=r.prompt_len + sys_len,
                                       prefix_id="sys/toy",
                                       prefix_len=sys_len) for r in reqs]
    pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=args.max_new)
    for share in (False, True):
        spec = ReplicaSpec(4, 4 * (32 + args.max_new), speed=2,
                           prefill_tokens_per_step=4, page_size=4,
                           share_prefixes=share)
        st = SimEngine(policy=pol, predictor=pred, spec=spec).run(shared_reqs)
        print(f"      share_prefixes={str(share):5s} "
              f"p50={st.p50_latency:7.1f} p99={st.p99_latency:7.1f} "
              f"amp={st.kv_amplification:.3f} prefill={st.prefill_ticks} "
              f"saved={st.prefill_saved_ticks} hits={st.prefix_hits}")
    print("done — ProD scheduling/routing/stealing vs prediction-blind "
          "baselines shown above; stage 6 serves the trained head itself "
          "at dispatch time, stage 7 keeps it calibrated while the workload "
          "drifts, stage 8 keeps preempted requests' KV pages so resume "
          "skips the prefill recompute, stage 9 shares one physical copy "
          "of the system prompt across every concurrent request.")


if __name__ == "__main__":
    main()
