"""Known-good miniature two-path engine: every knob threaded through both
the reference and the vectorized/leap decode paths.  Parsed (never
executed) by tests/test_reprolint.py."""


class MiniEngine:
    def __init__(self, policy, spec):
        self.policy = policy
        self.spec = spec
        self._budget = spec.step_token_budget     # derived knob
        self.slots = []
        self.t = 0.0

    def _decode_tick_ref(self):
        sp = self.spec.speed
        cap = self.policy.max_seq_len
        quota = self._budget if self._budget is not None else cap
        for i, g in enumerate(self.slots):
            self.slots[i] = min(g + min(sp, quota), cap)
            quota -= sp

    def _decode_tick_vec(self):
        sp = self.spec.speed
        cap = self.policy.max_seq_len
        quota = self._budget if self._budget is not None else cap
        self.slots = [min(g + min(sp, quota), cap) for g in self.slots]

    def ticks_to_event(self):
        sp = self.spec.speed
        if self._budget is not None and len(self.slots) * sp > self._budget:
            return 1.0
        return max((self.policy.max_seq_len - max(self.slots)) // sp, 1.0)

    def leap(self, q):
        sp = self.spec.speed
        cap = self.policy.max_seq_len
        self.t += q
        self.slots = [min(g + q * sp, cap) for g in self.slots]
