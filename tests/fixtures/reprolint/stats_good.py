"""Known-good conservation fixture: counters survive the merge, row()
surfaces everything, and emitted kinds match the registry exactly."""

from dataclasses import dataclass, field
from typing import List

EVENT_KINDS = ("arrival", "finish", "timeout")
TERMINAL_KINDS = ("finish", "timeout")


@dataclass
class ServeStats:
    policy: str
    completed: int = 0
    timed_out: int = 0

    def row(self) -> dict:
        return self.__dict__.copy()


@dataclass
class ClusterStats:
    policy: str
    completed: int = 0
    timed_out: int = 0
    stolen: int = 0
    replica_rows: List[dict] = field(default_factory=list)

    def row(self) -> dict:
        d = self.__dict__.copy()
        # surfaced per-replica, not as a scalar column
        d.pop("replica_rows")  # reprolint: disable=stats-exporter-surfacing
        return d


class SimEngine:
    def __init__(self, tracer):
        self.tracer = tracer
        self.completed = 0
        self.timed_out = 0

    def submit(self, r):
        self.tracer.emit(0.0, 0, r, "arrival")

    def finish(self, r):
        self.completed += 1
        self.tracer.emit(1.0, 0, r, "finish")

    def expire(self, r):
        self.timed_out += 1
        self.tracer.emit(1.0, 0, r, "timeout")

    def stats(self):
        return ServeStats(policy="fcfs", completed=self.completed,
                          timed_out=self.timed_out)


class Cluster:
    def __init__(self, engines):
        self.engines = engines
        self.stolen = 0

    def _stats(self):
        return ClusterStats(
            policy="fcfs",
            completed=sum(e.completed for e in self.engines),
            timed_out=sum(e.timed_out for e in self.engines),
            stolen=self.stolen,
            replica_rows=[e.stats().row() for e in self.engines],
        )
