"""Known-bad determinism fixture: one hazard per determinism sub-check.
Lives under a ``serving/`` component so the checker takes it in scope.
Parsed, never imported (np/time are deliberately not imported)."""

ORDERINGS = ("fcfs", "sjf")


class BadPolicy:
    def __init__(self, order="fcfs"):
        # BUG: never checked against ORDERINGS
        self.order = order


class BadScheduler:
    def __init__(self, policy):
        self.policy = policy
        self.waiting = set()

    def drain(self):
        done = []
        for rid in set(self.waiting):            # BUG: set iteration order
            done.append(rid)
        return done

    def tie_break(self, reqs):
        return sorted(reqs, key=lambda r: id(r))  # BUG: identity sort key

    def jitter(self):
        return np.random.rand()                  # BUG: global numpy RNG

    def jitter2(self):
        return random.random()                   # BUG: global python RNG

    def fresh_rng(self):
        return np.random.default_rng()           # BUG: unseeded

    def stamp(self):
        return time.time()                       # BUG: wall clock
