"""Known-good determinism fixture: the same jobs done reproducibly.
Lives under a ``serving/`` component so the checker takes it in scope."""

import numpy as np

ORDERINGS = ("fcfs", "sjf")


class GoodPolicy:
    def __init__(self, order="fcfs"):
        if order not in ORDERINGS:
            raise ValueError(f"order {order!r} not in {ORDERINGS}")
        self.order = order


class GoodScheduler:
    def __init__(self, policy, seed):
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        self.waiting = set()
        self.t = 0.0

    def drain(self):
        return [rid for rid in sorted(self.waiting)]

    def has(self, rid):
        # membership tests on sets are fine; only iteration order is hazardous
        return rid in self.waiting

    def tie_break(self, reqs):
        return sorted(reqs, key=lambda r: r.rid)

    def jitter(self):
        return self.rng.random()

    def stamp(self):
        return self.t
