"""Dispatch wrappers for the known-good kernel fixture (parse-only)."""

from .ref import toyfuse_ref
from .toyfuse import toyfuse_pallas


def toyfuse(x, w, impl="pallas"):
    if impl == "xla":
        return toyfuse_ref(x, w)
    return toyfuse_pallas(x, w, interpret=(impl == "interpret"))
