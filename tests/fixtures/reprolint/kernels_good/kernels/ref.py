"""Pure-jnp oracles for the known-good kernel fixture (parse-only)."""

import jax.numpy as jnp


def toyfuse_ref(x, w):
    return jnp.asarray(x) * jnp.asarray(w)
