"""Known-good fixture kernel: padded input (the ``%`` guard), literal
grid, index maps matching the grid rank.  Parse-only."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _toyfuse_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = x_ref[...] * w_ref[...]


def toyfuse_pallas(x, w, *, block=128, interpret=False):
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad),))
    wp = jnp.pad(w, ((0, pad),))
    nblocks = (n + pad) // block
    out = pl.pallas_call(
        _toyfuse_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, xp.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:n]
