"""Stand-in for the interpret-vs-xla sweep the checker cross-references.
The filename deliberately avoids the ``test_`` prefix so pytest never
collects it; reprolint's kernel-test-parity check parses every ``*.py``
under ``tests/``, prefix or not."""

IMPLS = ("interpret", "xla")


def sweep_toyfuse(toyfuse, x, w):
    return [toyfuse(x, w, impl=impl) for impl in IMPLS]
