"""Known-bad conservation fixture, one violation per sub-check:

* ``ServeStats.lost_counter`` has no ``ClusterStats`` counterpart;
* ``ClusterStats.stolen`` is declared but never passed at the merge site;
* ``ClusterStats.row()`` drops ``timed_out`` without a suppression;
* an emit site produces ``"vanished"`` which the registry doesn't declare;
* the registry declares ``"ghost"`` which nothing emits;
* ``TERMINAL_KINDS`` carries ``"rejected"`` which EVENT_KINDS lacks.
"""

from dataclasses import dataclass

EVENT_KINDS = ("arrival", "finish", "timeout", "ghost")
TERMINAL_KINDS = ("finish", "timeout", "rejected")


@dataclass
class ServeStats:
    policy: str
    completed: int = 0
    timed_out: int = 0
    lost_counter: int = 0

    def row(self) -> dict:
        return self.__dict__.copy()


@dataclass
class ClusterStats:
    policy: str
    completed: int = 0
    timed_out: int = 0
    stolen: int = 0

    def row(self) -> dict:
        d = self.__dict__.copy()
        d.pop("timed_out")
        return d


class SimEngine:
    def __init__(self, tracer):
        self.tracer = tracer
        self.completed = 0
        self.timed_out = 0
        self.lost_counter = 0

    def submit(self, r):
        self.tracer.emit(0.0, 0, r, "arrival")

    def finish(self, r):
        self.completed += 1
        self.tracer.emit(1.0, 0, r, "finish")

    def expire(self, r):
        self.timed_out += 1
        self.tracer.emit(1.0, 0, r, "timeout")

    def vanish(self, r):
        self.lost_counter += 1
        self.tracer.emit(1.0, 0, r, "vanished")

    def stats(self):
        return ServeStats(policy="fcfs", completed=self.completed,
                          timed_out=self.timed_out,
                          lost_counter=self.lost_counter)


class Cluster:
    def __init__(self, engines):
        self.engines = engines
        self.stolen = 0

    def _stats(self):
        return ClusterStats(
            policy="fcfs",
            completed=sum(e.completed for e in self.engines),
            timed_out=sum(e.timed_out for e in self.engines),
        )
