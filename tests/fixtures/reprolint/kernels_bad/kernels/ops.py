"""Wrappers for the known-bad kernel fixture: ``badkern`` has no wrapper
at all, and ``halfwired`` dispatches neither its kernel nor its oracle."""


def halfwired(x, impl="pallas"):
    # BUG: neither halfwired_pallas nor halfwired_ref is ever called
    return x
