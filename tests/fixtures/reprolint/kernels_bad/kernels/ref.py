"""Oracles for the known-bad kernel fixture: ``halfwired_ref`` exists but
is never wired into its wrapper; ``badkern`` has no oracle at all."""

import jax.numpy as jnp


def halfwired_ref(x):
    return jnp.asarray(x) + 1
