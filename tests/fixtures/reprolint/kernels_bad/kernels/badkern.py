"""Known-bad fixture kernels, one violation per kernel-contract sub-check:

* ``badkern_pallas`` — no ``badkern_ref``, no ``ops.badkern`` wrapper, no
  interpret test, an unguarded ``//`` grid (no ``%`` padding in scope),
  and a 2-arg index map against a 1-d grid;
* ``halfwired_pallas`` — wrapper and oracle exist but the wrapper calls
  neither.

Parse-only.
"""

import jax
from jax.experimental import pallas as pl


def _badkern_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def badkern_pallas(x, *, block=128, interpret=False):
    g = x.shape[0] // block   # BUG: remainder block silently dropped
    return pl.pallas_call(
        _badkern_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block,), lambda i, j: (i,))],  # BUG: arity
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def halfwired_pallas(x, *, interpret=False):
    return x + 1
