"""Known-bad miniature two-path engine: the vectorized tick forgot the
``policy.max_seq_len`` cap the reference tick applies (the classic
unthreaded-knob bug), and the leap machinery consults a knob
(``spec.burst_len``) the reference path never reads."""


class MiniEngine:
    def __init__(self, policy, spec):
        self.policy = policy
        self.spec = spec
        self._budget = spec.step_token_budget
        self.slots = []
        self.t = 0.0

    def _decode_tick_ref(self):
        sp = self.spec.speed
        cap = self.policy.max_seq_len
        quota = self._budget if self._budget is not None else cap
        for i, g in enumerate(self.slots):
            self.slots[i] = min(g + min(sp, quota), cap)
            quota -= sp

    def _decode_tick_vec(self):
        # BUG: no policy.max_seq_len cap — paths diverge at the cap
        sp = self.spec.speed
        quota = self._budget if self._budget is not None else 1 << 30
        self.slots = [g + min(sp, quota) for g in self.slots]

    def ticks_to_event(self):
        sp = self.spec.speed
        # BUG: burst_len gates the leap but the reference loop ignores it
        if len(self.slots) * sp > self.spec.burst_len:
            return 1.0
        if self._budget is not None and len(self.slots) * sp > self._budget:
            return 1.0
        return max((self.policy.max_seq_len - max(self.slots)) // sp, 1.0)

    def leap(self, q):
        sp = self.spec.speed
        cap = self.policy.max_seq_len
        self.t += q
        self.slots = [min(g + q * sp, cap) for g in self.slots]
