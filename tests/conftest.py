"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real 1-CPU default (the dry-run sets its own)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
