"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real 1-CPU default (the dry-run sets its own)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def shared_head():
    """One small trained ProD-D head (llama/math, 512 cap, 16 log bins,
    seed 5) shared by test_predictor_in_loop, test_adaptation and
    test_posterior_refine.

    ``fit_trace_head`` is deterministic in ``(cfg.settings(), cfg.view,
    cfg.max_seq_len, seed)`` and independent of the trace pattern/seed, so
    the per-module fixtures those files used to train were bit-identical
    weights — session scope trains them once (~2.5 s saved per extra module).
    """
    from repro.serving.arrivals import TraceConfig
    from repro.serving.predictor import fit_trace_head

    cfg = TraceConfig(n_requests=8, model="llama", scenario="math",
                      max_seq_len=512)
    return fit_trace_head(cfg, n_train=400, r=6, n_bins=16, hidden=32, seed=5)
