"""reprolint self-tests.

Fixture-proven true-positive and true-negative per checker family
(``tests/fixtures/reprolint/``), suppression-grammar and baseline
round-trips, the JSON report schema, the live-repo-matches-baseline
self-check, and mutation smoke tests that delete a single knob read from
one decode path of the *real* engine and demand the dual-path checker
notice.
"""

import ast
import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:           # `python -m pytest` from the
    sys.path.insert(0, str(REPO_ROOT))       # repo root already has it

from tools.reprolint import Project, run_checkers            # noqa: E402
from tools.reprolint.__main__ import main as reprolint_main  # noqa: E402
from tools.reprolint.baseline import (                       # noqa: E402
    diff_baseline, load_baseline, save_baseline)
from tools.reprolint.checkers import ALL_CHECKERS            # noqa: E402
from tools.reprolint.checkers.conservation import (          # noqa: E402
    ConservationChecker)
from tools.reprolint.checkers.determinism import (           # noqa: E402
    DeterminismChecker)
from tools.reprolint.checkers.dual_path import (             # noqa: E402
    DualPathChecker)
from tools.reprolint.checkers.kernel_contracts import (      # noqa: E402
    KernelContractChecker)
from tools.reprolint.reporters import report_json            # noqa: E402

FIXTURES = REPO_ROOT / "tests" / "fixtures" / "reprolint"
ENGINE = REPO_ROOT / "src" / "repro" / "serving" / "engine.py"
BASELINE = REPO_ROOT / "tools" / "reprolint" / "baseline.json"


def run_on(root, paths, checker):
    project = Project(root, paths)
    assert not project.errors, project.errors
    return run_checkers(project, [checker])


def keys(findings):
    return {f.key for f in findings}


# -- checker (1): dual-path knob parity ------------------------------------

def test_dual_path_good_engine_is_clean():
    active, suppressed = run_on(FIXTURES, [FIXTURES / "engine_good.py"],
                                DualPathChecker())
    assert active == [] and suppressed == []


def test_dual_path_bad_engine_both_groups_both_directions():
    active, _ = run_on(FIXTURES, [FIXTURES / "engine_bad.py"],
                       DualPathChecker())
    assert keys(active) == {
        # vec tick forgot the cap the reference tick applies
        "tick:policy.max_seq_len:unread-on:vectorized tick",
        # leap machinery consults a knob the reference path never reads
        "path:spec.burst_len:unread-on:reference path",
    }
    by_key = {f.key: f for f in active}
    assert all(f.check == "dual-path-knob-parity" for f in active)
    assert "max_seq_len" in by_key[
        "tick:policy.max_seq_len:unread-on:vectorized tick"].message


# -- checker (2): stats conservation / tracer kinds ------------------------

def test_conservation_good_stats_clean_with_one_suppression():
    active, suppressed = run_on(FIXTURES, [FIXTURES / "stats_good.py"],
                                ConservationChecker())
    assert active == []
    # replica_rows is deliberately popped from row(), with a justification
    assert keys(suppressed) == {"unsurfaced:replica_rows"}


def test_conservation_bad_stats_one_finding_per_subcheck():
    active, _ = run_on(FIXTURES, [FIXTURES / "stats_bad.py"],
                       ConservationChecker())
    assert keys(active) == {
        "unmerged-field:lost_counter",          # no ClusterStats twin
        "unaggregated:ClusterStats.stolen",     # declared, never passed
        "unsurfaced:timed_out",                 # popped without suppression
        "unregistered:vanished",                # emitted, not declared
        "unemitted:ghost",                      # declared, never emitted
        "terminal-unregistered:rejected",       # TERMINAL ⊄ EVENT_KINDS
    }


# -- checker (3): determinism hazards --------------------------------------

def test_determinism_good_serving_module_is_clean():
    active, suppressed = run_on(
        FIXTURES, [FIXTURES / "serving" / "det_good.py"],
        DeterminismChecker())
    assert active == [] and suppressed == []


def test_determinism_bad_serving_module_one_finding_per_hazard():
    active, _ = run_on(FIXTURES, [FIXTURES / "serving" / "det_bad.py"],
                       DeterminismChecker())
    assert keys(active) == {
        "set-iteration",
        "id-call",
        "np-global:rand",
        "py-global:random",
        "default-rng-unseeded",
        "clock:time.time",
        "unvalidated:order",
    }


def test_determinism_scope_is_the_serving_layer(tmp_path):
    hazard = "import time\n\n\ndef stamp():\n    return time.time()\n"
    (tmp_path / "serving").mkdir()
    (tmp_path / "serving" / "mod.py").write_text(hazard)
    (tmp_path / "other.py").write_text(hazard)
    active, _ = run_on(tmp_path, [tmp_path], DeterminismChecker())
    assert [f.path for f in active] == ["serving/mod.py"]


# -- checker (4): Pallas kernel contracts ----------------------------------

def test_kernel_contracts_good_package_is_clean():
    root = FIXTURES / "kernels_good"
    active, suppressed = run_on(root, [root / "kernels"],
                                KernelContractChecker())
    assert active == [] and suppressed == []


def test_kernel_contracts_bad_package_every_subcheck():
    root = FIXTURES / "kernels_bad"
    active, _ = run_on(root, [root / "kernels"], KernelContractChecker())
    assert keys(active) == {
        "no-ref:badkern", "no-op:badkern", "untested:badkern",
        "unguarded-floordiv", "arity:2-vs-1",
        "op-no-pallas:halfwired", "op-no-ref:halfwired",
        "untested:halfwired",
    }
    severities = {f.key: f.severity for f in active}
    assert severities["unguarded-floordiv"] == "warning"
    assert severities["no-ref:badkern"] == "error"


# -- suppressions ----------------------------------------------------------

_HAZARD = "import time\n\n\ndef stamp():\n    return time.time()\n"


def _det_run(tmp_path, text):
    (tmp_path / "serving").mkdir(exist_ok=True)
    (tmp_path / "serving" / "mod.py").write_text(text)
    return run_on(tmp_path, [tmp_path], DeterminismChecker())


def test_line_suppression_with_justification(tmp_path):
    text = _HAZARD.replace(
        "time.time()",
        "time.time()  # reprolint: disable=wall-clock -- fixture clock")
    active, suppressed = _det_run(tmp_path, text)
    assert active == [] and keys(suppressed) == {"clock:time.time"}


def test_symbol_level_suppression_on_def_header(tmp_path):
    text = _HAZARD.replace(
        "def stamp():",
        "def stamp():  # reprolint: disable=wall-clock -- whole symbol")
    active, suppressed = _det_run(tmp_path, text)
    assert active == [] and keys(suppressed) == {"clock:time.time"}


def test_file_level_suppression(tmp_path):
    active, suppressed = _det_run(
        tmp_path, "# reprolint: disable-file=wall-clock\n" + _HAZARD)
    assert active == [] and keys(suppressed) == {"clock:time.time"}


def test_suppression_is_check_specific(tmp_path):
    # disabling a *different* check must not silence the wall-clock finding
    text = _HAZARD.replace(
        "time.time()",
        "time.time()  # reprolint: disable=set-iteration-order")
    active, suppressed = _det_run(tmp_path, text)
    assert keys(active) == {"clock:time.time"} and suppressed == []


def test_any_site_suppression_of_multisite_finding(tmp_path):
    # acknowledging one read site of an asymmetric knob acknowledges the
    # knob: the engine_bad burst_len finding has its sites in ticks_to_event
    text = (FIXTURES / "engine_bad.py").read_text().replace(
        "self.spec.burst_len:",
        "self.spec.burst_len:"
        "  # reprolint: disable=dual-path-knob-parity -- lookahead only")
    (tmp_path / "engine_bad.py").write_text(text)
    active, suppressed = run_on(tmp_path, [tmp_path], DualPathChecker())
    assert "path:spec.burst_len:unread-on:reference path" not in keys(active)
    assert "path:spec.burst_len:unread-on:reference path" in keys(suppressed)


# -- baseline --------------------------------------------------------------

def test_baseline_roundtrip_and_diff(tmp_path):
    active, _ = run_on(FIXTURES, [FIXTURES / "engine_bad.py"],
                       DualPathChecker())
    assert len(active) == 2
    path = tmp_path / "baseline.json"
    save_baseline(path, active)
    entries = load_baseline(path)
    assert [tuple(e[k] for k in ("check", "path", "symbol", "key"))
            for e in entries] == sorted(f.identity for f in active)

    new, known, fixed = diff_baseline(active, entries)
    assert new == [] and len(known) == 2 and fixed == []

    new, known, fixed = diff_baseline(active, entries[:1])
    assert len(new) == 1 and len(known) == 1 and fixed == []

    new, known, fixed = diff_baseline(active[:1], entries)
    assert new == [] and len(known) == 1 and len(fixed) == 1


def test_baseline_rejects_unknown_version(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError, match="version"):
        load_baseline(path)


# -- JSON reporter ---------------------------------------------------------

def test_json_report_schema():
    active, suppressed = run_on(FIXTURES, [FIXTURES / "engine_bad.py"],
                                DualPathChecker())
    new, _, fixed = diff_baseline(active, [])
    doc = report_json(active, new, suppressed, fixed,
                      ["engine_bad.py"], None)
    assert doc["version"] == 1 and doc["tool"] == "reprolint"
    assert doc["baseline"] is None and doc["paths"] == ["engine_bad.py"]
    assert doc["counts"] == {"findings": 2, "new": 2, "suppressed": 0,
                             "fixed": 0}
    for f in doc["findings"]:
        assert {"check", "path", "line", "symbol", "key", "message",
                "severity", "new"} <= set(f)
        assert f["new"] is True
    json.dumps(doc)   # must be serializable as-is


# -- runner / live-repo self-check -----------------------------------------

def test_cli_gate_passes_on_live_repo():
    # the committed gate: src/ vs tools/reprolint/baseline.json
    assert reprolint_main(["src", "--root", str(REPO_ROOT)]) == 0


def test_live_findings_match_committed_baseline():
    project = Project(REPO_ROOT, [REPO_ROOT / "src"])
    active, _ = run_checkers(project, [cls() for cls in ALL_CHECKERS])
    new, _known, _fixed = diff_baseline(active, load_baseline(BASELINE))
    assert not new, [f.identity for f in new]


def test_cli_fails_on_findings_without_baseline(capsys):
    rc = reprolint_main([str(FIXTURES / "engine_bad.py"),
                         "--root", str(FIXTURES), "--no-baseline"])
    assert rc == 1
    assert "FAIL" in capsys.readouterr().err


def test_cli_baseline_write_then_gate_then_artifact(tmp_path):
    base = tmp_path / "baseline.json"
    report = tmp_path / "report.json"
    argv = [str(FIXTURES / "engine_bad.py"), "--root", str(FIXTURES),
            "--baseline", str(base)]
    assert reprolint_main(argv + ["--write-baseline"]) == 0
    assert reprolint_main(argv + ["--json", str(report)]) == 0
    doc = json.loads(report.read_text())
    assert doc["counts"] == {"findings": 2, "new": 0, "suppressed": 0,
                             "fixed": 0}


def test_cli_missing_baseline_is_an_error(tmp_path):
    rc = reprolint_main([str(FIXTURES / "engine_good.py"),
                         "--root", str(FIXTURES),
                         "--baseline", str(tmp_path / "nope.json")])
    assert rc == 1


# -- mutation smoke tests on the real engine -------------------------------

def _strip_knob_read(text, method, needle, replacement):
    """Replace ``needle`` on every line of ``method``'s body only."""
    tree = ast.parse(text)
    fn = next(n for n in ast.walk(tree)
              if isinstance(n, ast.FunctionDef) and n.name == method)
    lines = text.splitlines(keepends=True)
    hit = False
    for i in range(fn.lineno - 1, fn.end_lineno):
        if needle in lines[i]:
            lines[i] = lines[i].replace(needle, replacement)
            hit = True
    assert hit, f"{needle!r} not found in {method}"
    return "".join(lines)


def _dual_path_on(tmp_path, text):
    (tmp_path / "engine.py").write_text(text)
    return run_on(tmp_path, [tmp_path / "engine.py"], DualPathChecker())


def test_real_engine_is_clean_under_dual_path(tmp_path):
    active, suppressed = _dual_path_on(tmp_path, ENGINE.read_text())
    assert active == []
    assert suppressed, "the documented asymmetries should be suppressed, " \
                       "not absent"


@pytest.mark.parametrize("method,side", [
    ("_decode_tick_vec", "vectorized tick"),
    ("_decode_tick_ref", "reference tick"),
])
def test_mutation_deleting_one_knob_read_fails(tmp_path, method, side):
    mutated = _strip_knob_read(ENGINE.read_text(), method,
                               "self.spec.speed", "8")
    active, _ = _dual_path_on(tmp_path, mutated)
    assert f"tick:spec.speed:unread-on:{side}" in keys(active)


def test_mutation_removing_suppressions_surfaces_findings(tmp_path):
    text = ENGINE.read_text().replace(
        "# reprolint: disable=dual-path-knob-parity", "#")
    active, _ = _dual_path_on(tmp_path, text)
    assert active, "stripping the inline suppressions must resurface the " \
                   "acknowledged asymmetries"
    assert all(f.check == "dual-path-knob-parity" for f in active)


# -- satellite: Policy eager knob validation -------------------------------

def test_policy_rejects_unknown_order_and_reserve():
    from repro.serving.scheduler import Policy
    with pytest.raises(ValueError, match="order"):
        Policy(order="not-an-ordering")
    with pytest.raises(ValueError, match="reserve"):
        Policy(reserve="not-a-reserve-mode")
    Policy()   # defaults stay valid
