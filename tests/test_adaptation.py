"""Online adaptation subsystem: drift-aware traces, adaptive conformal
reservation calibration, predictor refresh, SLO-aware admission, and
steal-cost modeling — including the closed-loop vec-vs-ref bit-exactness
sweeps over drift × admission × steal-cost."""

import dataclasses

import numpy as np
import pytest

from repro.serving.adaptation import (AdaptationConfig, AdmissionController,
                                      OnlineAdapter, coverage_of, refit_head)
from repro.serving.arrivals import (DriftSpec, LatentOracle, TraceConfig,
                                    make_trace, mean_true_length, stable_rate)
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.predictor import PredictorService
from repro.serving.request import Request
from repro.serving.scheduler import Policy

QPOL = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)

# feasible-load arrival rate for a 4x8-slot homogeneous cluster over the
# llama/math law (mean length ~145): adaptation needs timely feedback, so
# the closed-loop tests run the cluster where completions keep up
RATE_4X8 = stable_rate(4, 8, mean_true_length(
    make_trace(TraceConfig(n_requests=500, rate=1.0, seed=0, model="llama",
                           scenario="math", max_seq_len=512))), 0.7)


def _trace(n=1000, rate=RATE_4X8, seed=0, **kw):
    kw.setdefault("model", "llama")
    kw.setdefault("scenario", "math")
    kw.setdefault("max_seq_len", 512)
    return make_trace(TraceConfig(n_requests=n, rate=rate, seed=seed, **kw))


def _cluster(predictor, n_replicas=4, slots=8, **kw):
    return Cluster.uniform(n_replicas, slots, 4 * (256 + 512), QPOL,
                           router="psq", predictor=predictor, **kw)


def _done(cl):
    return [r for e in cl.engines for r in e.done]


def _coverage(reqs):
    return coverage_of(reqs)


# ---------------------------------------------------------------------------
# drift-aware traces
# ---------------------------------------------------------------------------


class TestDriftTraces:
    def test_no_drift_is_bit_identical(self):
        """drift=None and a DriftSpec whose switch falls past the trace end
        both reproduce the stationary trace exactly (no extra rng draws)."""
        plain = _trace(400, seed=3)
        never = _trace(400, seed=3,
                       drift=DriftSpec(switch_step=1e12, scale_mult=2.0))
        for a, b in zip(plain, never):
            assert (a.rid, a.arrival, a.prompt_len, a.true_len) == \
                   (b.rid, b.arrival, b.prompt_len, b.true_len)
            np.testing.assert_array_equal(a.phi, b.phi)

    def test_scale_drift_inflates_lengths_not_features(self):
        """Post-switch true lengths grow by ~scale_mult while the feature
        distribution stays put — the drift is invisible in φ."""
        switch = 2000.0
        reqs = _trace(6000, rate=1.0, seed=1, max_seq_len=1 << 15,
                      drift=DriftSpec(switch_step=switch, scale_mult=1.6))
        pre = [r for r in reqs if r.arrival < switch]
        post = [r for r in reqs if r.arrival >= switch]
        lp = np.mean([r.true_len for r in pre])
        lq = np.mean([r.true_len for r in post])
        assert lq / lp == pytest.approx(1.6, rel=0.15)
        # φ (log-median coordinate) keeps its pre-drift distribution
        fp = np.mean([r.phi[0] for r in pre])
        fq = np.mean([r.phi[0] for r in post])
        assert abs(fq - fp) < 0.1

    def test_ramp_interpolates_scale(self):
        spec = DriftSpec(switch_step=1000.0, scale_mult=2.0, ramp_steps=1000.0)
        t = np.array([0.0, 999.0, 1500.0, 2000.0, 5000.0])
        s = np.exp(spec.log_scale_at(t))
        assert s[0] == s[1] == 1.0
        assert s[2] == pytest.approx(np.sqrt(2.0))
        assert s[3] == s[4] == pytest.approx(2.0)

    def test_mix_shift_changes_composition(self):
        """Post-switch arrivals re-draw their scenario from mix_weights —
        here everything becomes chat."""
        w = tuple(1.0 if s == ("qwen", "chat") else 0.0
                  for s in TraceConfig(model="mix", scenario="mix").settings())
        reqs = _trace(3000, rate=1.0, seed=2, model="mix", scenario="mix",
                      drift=DriftSpec(switch_step=1500.0, mix_weights=w))
        pre = {r.setting for r in reqs if r.arrival < 1500.0}
        post = {r.setting for r in reqs if r.arrival >= 1500.0}
        assert len(pre) == 8
        assert post == {"qwen/chat"}

    def test_drift_validation(self):
        with pytest.raises(ValueError):
            DriftSpec(switch_step=-1.0)
        with pytest.raises(ValueError):
            DriftSpec(switch_step=0.0, scale_mult=0.0)
        with pytest.raises(ValueError):
            DriftSpec(switch_step=0.0, ramp_steps=-2.0)
        with pytest.raises(ValueError):
            make_trace(TraceConfig(
                n_requests=10, model="llama", scenario="math",
                drift=DriftSpec(switch_step=0.0, mix_weights=(1.0, 1.0))))

    def test_drift_trace_deterministic(self):
        kw = dict(drift=DriftSpec(switch_step=500.0, scale_mult=1.4,
                                  ramp_steps=200.0))
        a, b = _trace(300, seed=9, **kw), _trace(300, seed=9, **kw)
        assert [(r.rid, r.arrival, r.true_len) for r in a] == \
               [(r.rid, r.arrival, r.true_len) for r in b]


# ---------------------------------------------------------------------------
# adaptive conformal calibration
# ---------------------------------------------------------------------------


class TestAdaptiveConformal:
    def test_static_adapter_matches_open_loop(self):
        """gamma=0, no refresh: the closed loop (dispatch-time annotation,
        feedback checkpoints) must reproduce the plain open-loop run exactly
        — annotation values are batching-invariant and nothing adapts."""
        reqs = _trace(600, seed=4)
        plain = _cluster(LatentOracle()).run(reqs)
        ad = OnlineAdapter(LatentOracle(), AdaptationConfig(gamma=0.0))
        closed = _cluster(ad).run(reqs)
        assert plain.row() == closed.row()
        assert ad.observed == closed.completed
        assert ad.q_eff == QPOL.quantile                # never moved

    def test_coverage_converges_on_stationary_trace(self):
        """ACI drives realized reservation coverage to the target on a
        stationary trace, correcting the base predictor's feature-noise
        under-coverage (~0.84 at nominal q0.9 on llama/math)."""
        reqs = _trace(3000, seed=0)
        static = OnlineAdapter(LatentOracle(), AdaptationConfig(gamma=0.0))
        _cluster(static).run(reqs)
        adapt = OnlineAdapter(LatentOracle(), AdaptationConfig(gamma=0.01))
        _cluster(adapt).run(reqs)
        target = 0.9
        assert static.coverage() < target - 0.03       # the bias is real
        assert abs(adapt.rolling_coverage() - target) <= 0.05
        assert abs(adapt.coverage() - target) \
            < abs(static.coverage() - target)

    def test_coverage_recovers_after_abrupt_switch(self):
        """Mild scale drift: the frozen quantile's post-switch coverage
        collapses; the ACI-adjusted quantile recovers it near target."""
        switch = 0.5 * 3000 / RATE_4X8
        reqs = _trace(3000, seed=1,
                      drift=DriftSpec(switch_step=switch, scale_mult=1.15))

        def post_cov(gamma):
            ad = OnlineAdapter(LatentOracle(), AdaptationConfig(gamma=gamma))
            cl = _cluster(ad)
            cl.run(reqs)
            post = [r for r in _done(cl) if r.arrival >= switch]
            return _coverage(post)

        static, adapted = post_cov(0.0), post_cov(0.01)
        assert static <= 0.80                  # degraded >= 0.10 from target
        assert adapted >= static + 0.05
        assert abs(adapted - 0.9) <= 0.08

    def test_quantile_moves_toward_coverage_gap(self):
        """Unit-level ACI semantics: misses push the effective quantile up
        by gamma*target, covers pull it down by gamma*(1-target), clamped."""
        ad = OnlineAdapter(LatentOracle(),
                           AdaptationConfig(gamma=0.1, q_min=0.5,
                                            q_max=0.995))
        ad.q_eff = 0.9

        def obs(true_len, cal_q):
            r = Request(rid=0, arrival=0.0, prompt_len=8, true_len=true_len)
            r.cal_q = cal_q
            r.predicted_len = float(cal_q)
            ad.observe([r])

        obs(100, 200.0)                                 # covered
        assert ad.q_eff == pytest.approx(0.9 - 0.1 * 0.1)
        obs(300, 200.0)                                 # miss
        assert ad.q_eff == pytest.approx(0.89 + 0.1 * 0.9, abs=1e-9)
        for _ in range(10):
            obs(300, 200.0)
        assert ad.q_eff == 0.995                        # clamped at q_max
        assert ad.observed == 12 and ad.miscovered == 11

    def test_config_validation(self):
        for bad in (dict(target_coverage=1.0), dict(gamma=-0.1),
                    dict(q_min=0.9, q_max=0.8), dict(window=0),
                    dict(every=0), dict(buffer_size=0)):
            with pytest.raises(ValueError):
                AdaptationConfig(**bad)


# ---------------------------------------------------------------------------
# predictor refresh
# ---------------------------------------------------------------------------


TRAIN_CFG = TraceConfig(n_requests=1000, rate=RATE_4X8, seed=11,
                        model="llama", scenario="math", max_seq_len=512)


@pytest.fixture(scope="module")
def head(shared_head):
    """The session-scoped ProD-D head (conftest ``shared_head``) — identical
    weights to ``fit_trace_head(TRAIN_CFG, n_train=400, r=6, n_bins=16,
    hidden=32, seed=5)`` since the fit ignores the trace pattern/seed."""
    return shared_head


class TestRefresh:
    def test_swap_weights_invalidates_cache(self, head):
        """Satellite: a weight swap must version/invalidate the LRU so stale
        predictions can never be served, and count in ServiceStats.row()."""
        svc = PredictorService(head, window=8.0)
        reqs = [r.fresh_copy() for r in make_trace(TRAIN_CFG)[:32]]
        svc.annotate(reqs, QPOL)
        before = [r.predicted_len for r in reqs]
        hits_before = svc.stats.cache_hits
        # refit on shifted targets -> different weights -> different preds
        phi = np.stack([r.phi for r in reqs])
        new = refit_head(head, phi, np.full(len(reqs), 500.0), epochs=40,
                         seed=0)
        svc.swap_weights(new)
        again = [r.fresh_copy() for r in make_trace(TRAIN_CFG)[:32]]
        svc.annotate(again, QPOL)
        after = [r.predicted_len for r in again]
        assert svc.stats.cache_hits == hits_before   # no stale LRU hits
        assert svc.stats.row()["refreshes"] == 1
        assert not np.allclose(before, after)
        assert np.mean(after) > np.mean(before)      # learned longer lengths

    def test_refit_head_is_incremental_and_deterministic(self, head):
        phi = np.random.default_rng(0).normal(size=(64, 4))
        lens = np.full(64, 300.0)
        a = refit_head(head, phi, lens, epochs=2, seed=3)
        b = refit_head(head, phi, lens, epochs=2, seed=3)
        import numpy.testing as npt
        for k in a.params:
            npt.assert_array_equal(np.asarray(a.params[k]),
                                   np.asarray(b.params[k]))
        # same bin edges: the swap is drop-in for the live service
        npt.assert_array_equal(np.asarray(a.edges), np.asarray(head.edges))

    def test_refresh_improves_post_drift_mae(self, head):
        """Scale drift the features cannot see: the frozen head's point
        predictions undershoot post-switch; warm-start refits on the
        completion buffer recover most of the error."""
        switch = 0.5 * 1500 / RATE_4X8
        reqs = _trace(1500, seed=11,
                      drift=DriftSpec(switch_step=switch, scale_mult=1.8))
        # score the settled regime: completions arriving in the last quarter
        # of the trace, well after the first post-drift refits landed
        tail_from = 0.75 * 1500 / RATE_4X8

        def tail_mae(refresh):
            # small buffer on purpose: post-drift completions dominate the
            # refit data soon after the switch
            cfg = AdaptationConfig(
                gamma=0.01, window=128, every=16,
                refresh_every=(switch / 5.0) if refresh else 0.0,
                refresh_min_samples=128, refresh_epochs=60, buffer_size=256,
                refresh_seed=7)
            ad = OnlineAdapter(PredictorService(head, window=8.0), cfg)
            cl = _cluster(ad)
            cl.run(reqs)
            tail = [r for r in _done(cl) if r.arrival >= tail_from]
            mae = float(np.mean([abs(r.predicted_len - r.true_len)
                                 for r in tail]))
            return mae, ad

        mae_static, _ = tail_mae(False)
        mae_refresh, ad = tail_mae(True)
        assert ad.refreshes > 0
        assert ad.base.stats.refreshes == ad.refreshes
        assert mae_refresh < 0.75 * mae_static

    def test_refresh_requires_swap_capable_base(self):
        """A weight-less base predictor (LatentOracle) never refreshes."""
        ad = OnlineAdapter(LatentOracle(),
                           AdaptationConfig(refresh_every=10.0,
                                            refresh_min_samples=1))
        r = Request(rid=0, arrival=0.0, prompt_len=8, true_len=50,
                    phi=np.zeros(4))
        r.cal_q, r.predicted_len = 40.0, 40.0
        ad.observe([r])
        assert ad.maybe_refresh(1e9) is False
        assert ad.refreshes == 0

    def test_mae_alarm_triggers_refresh(self, head):
        """Drift alarm path: no scheduled refresh, but a windowed MAE blowup
        past mult x baseline fires a refit (after the cooldown window)."""
        switch = 0.5 * 1500 / RATE_4X8
        reqs = _trace(1500, seed=13,
                      drift=DriftSpec(switch_step=switch, scale_mult=2.0))
        cfg = AdaptationConfig(gamma=0.0, window=64, every=16,
                               refresh_every=0.0, mae_alarm_mult=1.5,
                               refresh_min_samples=64, refresh_epochs=2,
                               buffer_size=512)
        ad = OnlineAdapter(PredictorService(head, window=8.0), cfg)
        _cluster(ad).run(reqs)
        assert ad.refreshes > 0


# ---------------------------------------------------------------------------
# SLO-aware admission control
# ---------------------------------------------------------------------------


class TestAdmission:
    def _run(self, load, admission, seed=6, n=1200):
        # RATE_4X8 targets 0.7 utilization of a 4x8 fleet; this class serves
        # a 2x4 fleet (1/4 the capacity), so rescale to make `load` the true
        # decode-utilization target
        rate = load * RATE_4X8 / 0.7 / 4.0
        reqs = _trace(n, rate=rate, seed=seed, slo_factor=3.0, slo_floor=50.0)
        cl = _cluster(LatentOracle(), n_replicas=2, slots=4,
                      admission=admission)
        return cl.run(reqs), cl, reqs

    def test_rejects_monotone_in_load(self):
        rejects = [self._run(load, AdmissionController())[0].rejected
                   for load in (0.4, 0.9, 1.6)]
        assert rejects == sorted(rejects)
        assert rejects[0] < rejects[-1]
        assert rejects[-1] > 0

    def test_rejected_is_distinct_and_partitions(self):
        st, cl, reqs = self._run(1.4, AdmissionController())
        assert st.rejected == len(cl.rejected_requests) > 0
        assert st.completed + st.timed_out + st.dropped + st.rejected \
            == len(reqs)
        # rejected requests never entered an engine
        done_rids = {r.rid for r in _done(cl)}
        for r in cl.rejected_requests:
            assert r.rid not in done_rids
            assert r.replica is None and r.t_start is None

    def test_admission_converts_timeouts_to_early_rejects(self):
        """Under overload, rejecting infeasible work early must not lose
        goodput and should slash late timeouts."""
        off, _, _ = self._run(1.6, None)
        on, _, _ = self._run(1.6, AdmissionController())
        assert on.timed_out < off.timed_out
        assert on.goodput >= 0.9 * off.goodput

    def test_deadline_less_requests_always_admitted(self):
        reqs = _trace(400, seed=7)                      # no SLOs configured
        st = _cluster(LatentOracle(),
                      admission=AdmissionController()).run(reqs)
        assert st.rejected == 0
        assert st.completed == len(reqs)

    def test_admission_validation(self):
        with pytest.raises(ValueError):
            AdmissionController(slack=0.0)


# ---------------------------------------------------------------------------
# steal-cost modeling
# ---------------------------------------------------------------------------


class TestStealCost:
    SPECS = (ReplicaSpec(2, 256 + 512, speed=1),
             ReplicaSpec(8, 4 * (256 + 512), speed=3))

    def _run(self, cost, vectorized=True):
        reqs = _trace(400, pattern="bursty", rate=2.0, seed=8)
        cl = Cluster(self.SPECS, QPOL, router="round_robin",
                     predictor=LatentOracle(), rebalance_every=20,
                     steal_cost=cost, vectorized=vectorized)
        return cl.run(reqs), cl

    def test_delay_charged_and_counted(self):
        free, _ = self._run(0)
        paid, _ = self._run(25)
        assert free.steal_delay == 0
        assert paid.stolen > 0
        # the delay is page-proportional: steal_cost ticks per page moved
        # (page_size=1 here, so pages == prompt tokens re-transferred)
        assert paid.steal_pages >= paid.stolen
        assert paid.steal_delay == 25 * paid.steal_pages
        assert paid.completed == free.completed
        # delayed migration can only slow the drain down
        assert paid.makespan >= free.makespan

    def test_latency_counts_from_arrival_not_migration(self):
        _, cl = self._run(40)
        done = _done(cl)
        # stolen+delayed requests still measure wait from their arrival
        assert all(r.t_start >= r.arrival for r in done)

    def test_steal_cost_validation(self):
        with pytest.raises(ValueError):
            Cluster(self.SPECS, QPOL, steal_cost=-1)


# ---------------------------------------------------------------------------
# vec-vs-ref bit-exactness across the new paths
# ---------------------------------------------------------------------------


def _rows(maker, reqs):
    out = []
    for vec in (True, False):
        cl = maker(vec)
        st = cl.run(reqs)
        done = sorted((r.rid, r.t_start, r.t_finish) for r in _done(cl))
        out.append((st.row(), done))
    return out


class TestVecRefBitExactness:
    """Acceptance: every new engine/cluster path — drift traces, closed-loop
    conformal adaptation, admission control, steal cost, and their
    combination — stays bit-identical between the per-slot reference and the
    vectorized event-leap decode."""

    @pytest.mark.parametrize("feat", ["drift", "admission", "steal_cost",
                                      "all"])
    def test_cluster_features(self, feat):
        drift = DriftSpec(switch_step=300.0, scale_mult=1.4) \
            if feat in ("drift", "all") else None
        reqs = _trace(300, pattern="bursty", rate=1.2, seed=15,
                      slo_factor=3.0, slo_floor=50.0, drift=drift)
        kw = {}
        if feat in ("admission", "all"):
            kw["admission"] = AdmissionController()
        if feat in ("steal_cost", "all"):
            kw.update(rebalance_every=25, steal_cost=10)
        specs = (ReplicaSpec(4, 2 * (256 + 512), speed=2),
                 ReplicaSpec(2, 256 + 512, speed=1))
        a, b = _rows(
            lambda vec: Cluster(specs, QPOL, router="psq",
                                predictor=LatentOracle(), vectorized=vec,
                                **kw), reqs)
        assert a == b

    def test_closed_loop_conformal(self):
        reqs = _trace(400, pattern="bursty", rate=1.0, seed=16,
                      slo_factor=4.0, slo_floor=80.0,
                      drift=DriftSpec(switch_step=250.0, scale_mult=1.3))
        covs = []

        def maker(vec):
            ad = OnlineAdapter(LatentOracle(),
                               AdaptationConfig(gamma=0.02, every=16))
            covs.append(ad)
            return Cluster.uniform(3, 4, 2 * (256 + 512), QPOL, router="psq",
                                   predictor=ad, vectorized=vec,
                                   admission=AdmissionController())

        a, b = _rows(maker, reqs)
        assert a == b
        # the adapter state itself is part of the contract
        assert covs[0].row() == covs[1].row()
        assert covs[0].q_eff != pytest.approx(0.9)     # it actually adapted

    def test_closed_loop_with_refresh(self, head):
        """Weight swaps mid-run (warm-start refits) must also replay
        bit-identically — the refit consumes the same canonical completion
        buffer at the same tick in both decode paths."""
        switch = 0.5 * 500 / RATE_4X8
        reqs = _trace(500, seed=17,
                      drift=DriftSpec(switch_step=switch, scale_mult=1.6))

        def maker(vec):
            cfg = AdaptationConfig(gamma=0.01, every=16, window=64,
                                   refresh_every=switch / 2.0,
                                   refresh_min_samples=64, refresh_epochs=2,
                                   buffer_size=512)
            ad = OnlineAdapter(PredictorService(head, window=8.0), cfg)
            return Cluster.uniform(3, 4, 2 * (256 + 512), QPOL, router="psq",
                                   predictor=ad, vectorized=vec)

        a, b = _rows(maker, reqs)
        assert a == b
        assert a[0]["refreshes"] > 0

    def test_closed_loop_deterministic_replay(self):
        reqs = _trace(300, seed=18,
                      drift=DriftSpec(switch_step=200.0, scale_mult=1.3))

        def run_once():
            ad = OnlineAdapter(LatentOracle(), AdaptationConfig(gamma=0.02))
            return _cluster(ad, admission=AdmissionController()) \
                .run(reqs).row()

        assert run_once() == run_once()

    def test_rerun_restores_pristine_weights(self, head):
        """Re-running the SAME cluster/adapter must replay identically even
        when the first run refreshed the head: reset() restores the base
        service's original weights, so run 2 never starts from run 1's
        refitted predictor."""
        switch = 0.5 * 500 / RATE_4X8
        reqs = _trace(500, seed=19,
                      drift=DriftSpec(switch_step=switch, scale_mult=1.6))
        cfg = AdaptationConfig(gamma=0.01, every=16, window=64,
                               refresh_every=switch / 2.0,
                               refresh_min_samples=64, refresh_epochs=2,
                               buffer_size=512)
        ad = OnlineAdapter(PredictorService(head, window=8.0), cfg)
        cl = _cluster(ad, n_replicas=3, slots=4)
        r1 = cl.run(reqs).row()
        assert r1["refreshes"] > 0                     # weights were swapped
        r2 = cl.run(reqs).row()
        assert r1 == r2
