"""Ref-counted shared prefix pages + copy-on-write: pool invariants, the
no-sharing golden (bit-identical to the plain pool), session-trace
vectorized-vs-reference regressions, and prefix-affinity routing."""

import copy
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.serving.arrivals import (LatentOracle, TraceConfig, make_trace,
                                    stable_rate_specs)
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.kvcache import KVCacheManager
from repro.serving.scheduler import Policy

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _kv(budget=1024, ps=16, track=True):
    return KVCacheManager(budget_tokens=budget, page_size=ps,
                          track_pages=track, share_prefixes=True)


def _session_trace(n=260, seed=0, rate=0.6):
    """Small single-setting trace with system prompts + chat + agentic."""
    return make_trace(TraceConfig(
        n_requests=n, rate=rate, seed=seed, model="qwen", scenario="math",
        session_frac=0.3, agentic_frac=0.3, system_prompt_len=64,
        session_gap_mean=30.0, agentic_gap_mean=2.0, prompt_min=16,
        prompt_max=48, max_seq_len=512))


def _pages_allocated(kv):
    """Every allocated page, by owner: private page tables + prefix entries."""
    priv = sum(len(t) for t in kv.page_table.values())
    pfx = sum(len(e.ids) for e in kv.prefixes.values())
    return priv, pfx


def _check_conservation(kv):
    priv, pfx = _pages_allocated(kv)
    assert len(kv._free_ids) + priv + pfx == kv.pages_total
    ids = kv._free_ids + [i for t in kv.page_table.values() for i in t] \
        + [i for e in kv.prefixes.values() for i in e.ids]
    assert sorted(ids) == list(range(kv.pages_total))  # no leak, no double
    assert kv.pages_free == len(kv._free_ids)
    for e in kv.prefixes.values():
        assert e.refs >= 0
        assert e.pages == len(e.ids)


class TestSharedPool:
    def test_two_holders_share_physical_pages(self):
        kv = _kv()
        assert kv.admit(0, 96, "p", 64)     # miss: registers 4 prefix pages
        assert kv.admit(1, 96, "p", 64)     # hit: attaches to them
        assert kv.prefix_misses == 1 and kv.prefix_hits == 1
        # physical: 4 shared + 2x2 private; logical: 2 x 6 pages
        assert kv.reserved_now == (4 + 2 + 2) * 16
        assert kv.logical_now == 2 * 96
        assert kv.shared_now == 64 and kv.shared_pages == 4
        assert kv.shared_tokens_of(0) == 64 == kv.shared_tokens_of(1)
        assert kv.prefill_skip(1) == 64     # second admit skips the prefix
        assert kv.prefill_skip(0) == 0      # first one prefills it
        _check_conservation(kv)

    def test_no_page_freed_while_shared(self):
        kv = _kv(budget=256)
        assert kv.admit(0, 96, "p", 64)
        assert kv.admit(1, 96, "p", 64)
        free_before = kv.pages_free
        kv.release(0)
        # only rid 0's 2 private pages return; the 4 shared pages stay
        assert kv.pages_free == free_before + 2
        assert kv.prefixes["p"].refs == 1
        assert kv.shared_now == 64
        _check_conservation(kv)
        kv.release(1)
        # last holder gone: pages move to retained cache, still not free
        assert kv.pages_free == free_before + 4
        assert kv.prefixes["p"].refs == 0
        assert kv.shared_now == 0 and kv.cached_now == 64
        _check_conservation(kv)

    def test_retained_cache_revives_for_free(self):
        kv = _kv()
        assert kv.admit(0, 96, "p", 64)
        kv.release(0)
        assert kv.cached_now == 64
        assert kv.has_prefix("p")
        assert kv.admit(1, 96, "p", 64)     # revival: a hit, not a miss
        assert kv.prefix_hits == 1 and kv.prefix_misses == 1
        assert kv.cached_now == 0 and kv.shared_now == 64
        assert kv.prefill_skip(1) == 64
        _check_conservation(kv)

    def test_lru_eviction_only_under_pressure(self):
        kv = _kv(budget=8 * 16)             # 8 pages
        assert kv.admit(0, 32, "a", 32)     # 2 prefix pages
        kv.release(0)
        assert kv.admit(1, 32, "b", 32)     # 2 more
        kv.release(1)
        assert kv.cached_now == 64 and kv.prefix_evictions == 0
        # needs 6 pages, only 4 free: evicts "a" (oldest) then "b"
        assert kv.admit(2, 96)
        assert kv.prefix_evictions >= 1
        assert not kv.has_prefix("a")       # LRU order: "a" went first
        _check_conservation(kv)

    def test_cow_privatizes_boundary_page_and_preserves_totals(self):
        kv = _kv()
        assert kv.admit(0, 96, "p", 48)     # registers 3 full prefix pages
        # rid 1 diverges inside page 2 of the prefix (40 = 2 pages + 8 tokens)
        used_before = kv.used_now
        assert kv.admit(1, 96, "p", 40)
        assert kv.cow_copies == 1
        assert kv.shared_tokens_of(1) == 32  # only the 2 whole pages shared
        assert kv.prefill_skip(1) == 40      # copied content still skips
        assert kv.used_now == used_before    # cow moves pages, not usage
        kv.use(0, 50)
        kv.use(1, 60)
        assert kv.used_now == 110            # per-request used totals intact
        assert kv.used[0] == 50 and kv.used[1] == 60
        # both grants are full-size: the cow page is rid 1's own
        assert kv.reserved[0] == 96 == kv.reserved[1]
        assert kv.logical_now == 192
        assert kv.reserved_now == 192 - 32   # only 2 pages deduplicated
        _check_conservation(kv)

    def test_later_admit_extends_prefix(self):
        kv = _kv()
        assert kv.admit(0, 64, "p", 32)     # 2 prefix pages
        assert kv.admit(1, 128, "p", 96)    # extends the store to 6 pages
        assert kv.prefixes["p"].pages == 6
        assert kv.shared_tokens_of(1) == 96
        assert kv.prefill_skip(1) == 32     # only the resident part skips
        _check_conservation(kv)

    def test_shrink_never_gives_back_shared_pages(self):
        kv = _kv()
        assert kv.admit(0, 96, "p", 64)
        assert kv.shrink(0, 0) >= 64        # clamped at the shared tokens
        assert kv.shared_now == 64
        _check_conservation(kv)

    def test_kv_amplification_integral(self):
        kv = _kv()
        assert kv.admit(0, 96, "p", 64)
        assert kv.admit(1, 96, "p", 64)
        for _ in range(10):
            kv.tick()
        assert kv.kv_amplification == pytest.approx(192 / 128)
        assert kv.peak_logical > kv.peak_reserved

    def test_sharing_off_pool_is_bit_identical(self):
        """The same op stream on share_prefixes=False vs True (no prefixes
        declared) leaves identical books — sharing is pay-for-use."""
        a = KVCacheManager(budget_tokens=512, page_size=16, track_pages=True)
        b = _kv(budget=512)
        rng = np.random.default_rng(0)
        for step in range(200):
            rid = int(rng.integers(0, 6))
            op = int(rng.integers(0, 4))
            if op == 0 and rid not in a.reserved:
                n = int(rng.integers(1, 128))
                assert a.admit(rid, n) == b.admit(rid, n)
            elif op == 1 and rid in a.reserved:
                e = int(rng.integers(1, 32))
                assert a.grow(rid, e) == b.grow(rid, e)
            elif op == 2 and rid in a.reserved:
                a.use(rid); b.use(rid)
            elif op == 3 and rid in a.reserved:
                a.release(rid); b.release(rid)
            a.tick(); b.tick()
            assert (a.reserved, a.asked, a.used) == (b.reserved, b.asked, b.used)
            assert a.pages_free == b.pages_free
            assert a.total_reserved_steps == b.total_reserved_steps
            assert b.logical_now == b.reserved_now       # no sharing: equal
            assert b.kv_amplification == 1.0

    def test_can_reserve_iff_reserve_with_prefixes(self):
        """can_reserve == reserve-would-succeed, now over prefix-carrying
        admits against a crowded pool with reclaimable cache."""
        rng = np.random.default_rng(7)
        kv = _kv(budget=512)
        live = []
        for step in range(300):
            rid = int(rng.integers(0, 8))
            pid = ["p", "q", None][int(rng.integers(0, 3))]
            plen = int(rng.integers(0, 96))
            n = int(rng.integers(1, 256))
            probe = copy.deepcopy(kv)
            assert kv.can_reserve(rid, n, pid, plen) == \
                probe.reserve(rid, n, pid, plen)
            if kv.reserve(rid, n, pid, plen) and rid not in live:
                live.append(rid)
            if live and rng.random() < 0.3:
                kv.release(live.pop(int(rng.integers(0, len(live)))))
            _check_conservation(kv)


class TestSharedPoolProperties:
    @given(seed=st.integers(0, 2**32 - 1), ps=st.sampled_from([1, 7, 16]))
    def test_random_stream_invariants(self, seed, ps):
        """Random admit/grow/use/release streams with prefixes: refcounts
        never negative, pages conserved (free + private tables + prefix
        entries partition the pool), no page freed while shared, and the
        physical books never exceed the logical ones."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=ps * 40, page_size=ps,
                            track_pages=True, share_prefixes=True)
        live = []
        for step in range(120):
            op = int(rng.integers(0, 5))
            if op <= 1:
                rid = step
                pid = [None, "a", "b", "c"][int(rng.integers(0, 4))]
                plen = int(rng.integers(0, 5 * ps))
                n = int(rng.integers(1, 12 * ps))
                if kv.admit(rid, n, pid, plen):
                    live.append(rid)
            elif op == 2 and live:
                kv.grow(live[int(rng.integers(0, len(live)))],
                        int(rng.integers(1, 3 * ps)))
            elif op == 3 and live:
                kv.use(live[int(rng.integers(0, len(live)))])
            elif op == 4 and live:
                kv.release(live.pop(int(rng.integers(0, len(live)))))
            kv.tick()
            _check_conservation(kv)
            # private pages never exceed the logical grants backing them
            # (reserved_now itself may: a live prefix can hold pages beyond
            # what its current holders' grants cover, e.g. after the request
            # that extended it released)
            assert kv.reserved_now - kv.shared_now <= kv.logical_now
            # live prefix tokens == sum over refs>0 entries
            assert kv.shared_now == sum(
                e.pages for e in kv.prefixes.values() if e.refs > 0) * ps
            assert kv.cached_now == sum(
                e.pages for e in kv.prefixes.values() if e.refs == 0) * ps
        for rid in list(live):
            kv.release(rid)
        _check_conservation(kv)
        assert kv.shared_now == 0
        assert all(e.refs == 0 for e in kv.prefixes.values())


def _stats_and_finishes(cl, reqs):
    st_ = cl.run(reqs)
    done = sorted((r.rid, r.t_start, r.t_finish)
                  for e in cl.engines for r in e.done)
    return st_.row(), done


SPEC = ReplicaSpec(max_slots=8, kv_budget=4096, page_size=16,
                   prefill_tokens_per_step=64)
SHARED_SPEC = dataclasses.replace(SPEC, share_prefixes=True)
POL = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)


class TestEngineAndCluster:
    def test_sharing_off_cluster_bit_identical_on_session_trace(self):
        """share_prefixes=False must ignore prefix metadata entirely: a
        session trace replays bit-identically to the same trace with its
        prefix fields stripped (the PR-5 pool's view of it)."""
        reqs = _session_trace()
        bare = [dataclasses.replace(r, prefix_id=None, prefix_len=0)
                for r in reqs]
        pred = LatentOracle()
        a = _stats_and_finishes(Cluster([SPEC] * 2, POL, router="jsq",
                                        predictor=pred), reqs)
        b = _stats_and_finishes(Cluster([SPEC] * 2, POL, router="jsq",
                                        predictor=pred), bare)
        assert a == b

    @pytest.mark.parametrize("router", ["jsq", "prefix_affine"])
    def test_vec_matches_ref_with_sharing(self, router):
        """The event-leap fast path must stay bit-identical to the reference
        stepper with prefix sharing on and session traffic flowing."""
        reqs = _session_trace()
        pred = LatentOracle()
        v = _stats_and_finishes(
            Cluster([SHARED_SPEC] * 2, POL, router=router, predictor=pred,
                    vectorized=True), reqs)
        r = _stats_and_finishes(
            Cluster([SHARED_SPEC] * 2, POL, router=router, predictor=pred,
                    vectorized=False), reqs)
        assert v == r

    def test_engine_prefill_skip_saves_ticks(self):
        reqs = _session_trace(n=200)
        pred = LatentOracle()
        e_off = SimEngine(policy=POL, predictor=pred, spec=SPEC)
        e_on = SimEngine(policy=POL, predictor=pred, spec=SHARED_SPEC)
        s_off = e_off.run(reqs)
        s_on = e_on.run(reqs)
        assert s_off.prefill_saved_ticks == 0
        assert s_off.kv_amplification == 1.0
        assert s_on.prefill_saved_ticks > 0
        assert s_on.prefill_ticks < s_off.prefill_ticks
        assert s_on.kv_amplification > 1.0
        assert s_on.prefix_hits > 0
        assert len(e_on.done) == len(e_off.done) == len(reqs)

    def test_prefix_affine_equals_jsq_without_prefixes(self):
        reqs = make_trace(TraceConfig(n_requests=200, rate=0.6, seed=1,
                                      model="qwen", scenario="math",
                                      max_seq_len=512))
        pred = LatentOracle()
        a = _stats_and_finishes(Cluster([SHARED_SPEC] * 3, POL, router="jsq",
                                        predictor=pred), reqs)
        b = _stats_and_finishes(Cluster([SHARED_SPEC] * 3, POL,
                                        router="prefix_affine",
                                        predictor=pred), reqs)
        assert a[1] == b[1]

    def test_prefix_affine_routes_turns_to_holder(self):
        """Session turns follow their context: the affinity router lands
        more prefix hits (and skips more prefill) than jsq spreading."""
        reqs = _session_trace(n=400, rate=0.8)
        pred = LatentOracle()
        hits = {}
        for router in ("jsq", "prefix_affine"):
            cl = Cluster([SHARED_SPEC] * 3, POL, router=router,
                         predictor=pred)
            st_ = cl.run(reqs)
            hits[router] = (st_.prefix_hits, st_.prefill_saved_ticks)
            assert st_.completed == len(reqs)
        assert hits["prefix_affine"][0] > hits["jsq"][0]
        assert hits["prefix_affine"][1] > hits["jsq"][1]

    def test_prefix_imbalance_zero_is_pure_load_balancing(self):
        """With zero tolerated imbalance, affinity only fires on ties — the
        cluster still completes everything and stays balanced."""
        reqs = _session_trace(n=300, rate=0.8)
        cl = Cluster([SHARED_SPEC] * 3, POL, router="prefix_affine",
                     predictor=LatentOracle(), prefix_imbalance=0.0)
        st_ = cl.run(reqs)
        assert st_.completed == len(reqs)
        assert st_.balance < 2.0

    def test_session_trace_shape(self):
        """Generator wiring: system prompts lengthen every base prompt, turn
        requests extend their session's context, arrivals stay sorted per
        session, and prefix_len never exceeds prompt_len."""
        reqs = _session_trace(n=300)
        base = [r for r in reqs if r.rid < 300]
        turns = [r for r in reqs if r.rid >= 300]
        assert turns, "session knobs produced no turns"
        assert all(r.prefix_id == f"sys/{r.setting}" for r in base)
        assert all(0 <= r.prefix_len <= r.prompt_len for r in reqs)
        by_sid = {}
        for r in turns:
            assert r.prefix_id.startswith(("chat/", "agent/"))
            by_sid.setdefault(r.prefix_id, []).append(r)
        for sid, rs in by_sid.items():
            rs.sort(key=lambda r: r.rid)
            seed_rid = int(sid.split("/")[1])
            seed = next(r for r in reqs if r.rid == seed_rid)
            assert rs[0].arrival > seed.arrival
            for a, b in zip(rs, rs[1:]):
                assert b.arrival > a.arrival      # turns are causal
                assert b.prefix_len > a.prefix_len  # context keeps growing
            for r in rs:
                assert r.setting == seed.setting

    def test_no_session_knobs_trace_unchanged(self):
        """has_sessions=False leaves the base trace bit-identical — the
        session generator draws from its own RNG stream after the fact."""
        plain = make_trace(TraceConfig(n_requests=200, rate=0.7, seed=4,
                                       model="qwen", scenario="math",
                                       prompt_min=16, prompt_max=48,
                                       max_seq_len=512))
        with_knobs = _session_trace(n=200, seed=4, rate=0.7)
        base = [r for r in with_knobs if r.rid < 200]
        sys_len = 64
        for p, b in zip(plain, base):
            assert (p.rid, p.arrival, p.true_len) == (b.rid, b.arrival,
                                                      b.true_len)
            assert b.prompt_len == p.prompt_len + sys_len
            np.testing.assert_array_equal(p.phi, b.phi)
