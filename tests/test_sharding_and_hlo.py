"""Sharding-rule resolution, HLO analyzer, and a small-mesh dry-run smoke."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.sharding import default_rules, resolve_spec
from repro.launch import hlo_analysis as H


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh: rule RESOLUTION logic is mesh-shape independent given
    # divisibility, so we exercise fallbacks with a fake-shaped mesh object
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Shape-only stand-in for divisibility tests (no devices needed)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


class TestResolveSpec:
    def test_basic_mapping(self):
        m = FakeMesh(data=16, model=16)
        rules = {"batch": "data", "heads": "model", "seq": None}
        spec = resolve_spec(("batch", "seq", "heads"), (256, 4096, 64), m, rules)
        assert spec == P("data", None, "model")

    def test_divisibility_fallback_replicates(self):
        m = FakeMesh(data=16, model=16)
        rules = {"batch": "data", "kv": "model"}
        # kv=8 not divisible by 16 -> replicated
        spec = resolve_spec(("batch", "kv"), (256, 8), m, rules)
        assert spec == P("data")

    def test_batch_one_replicates(self):
        m = FakeMesh(data=16, model=16)
        rules = {"batch": "data"}
        spec = resolve_spec(("batch",), (1,), m, rules)
        assert spec == P()

    def test_axis_not_reused(self):
        m = FakeMesh(data=16, model=16)
        rules = {"a": "model", "b": "model"}
        spec = resolve_spec(("a", "b"), (64, 64), m, rules)
        assert spec == P("model")  # second claim dropped (trailing None trimmed)

    def test_tuple_axes_partial(self):
        m = FakeMesh(pod=2, data=16, model=16)
        rules = {"batch": ("pod", "data")}
        # 32 divisible by pod*data=32 -> both; 16 only by prefix (pod,)=2? no:
        spec32 = resolve_spec(("batch",), (32,), m, rules)
        assert spec32 == P(("pod", "data"))
        spec2 = resolve_spec(("batch",), (2,), m, rules)
        assert spec2 == P(("pod",))

    def test_default_rules_weights_not_data_sharded(self):
        m = FakeMesh(data=16, model=16)
        rules = default_rules(m)
        assert rules["embed"] is None
        assert rules["opt_embed"] is not None


HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %w = f32[128,128]{1,0} parameter(1)
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%dot.1), replica_groups={}
  ROOT %t = (s32[], f32[8,128]) tuple(%ar, %ar)
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %ag = f32[128,256]{1,0} all-gather(%a), dimensions={1}
  %while.1 = (s32[], f32[8,128]) while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%while.1), index=1
}
"""


class TestHloAnalyzer:
    def test_trip_count_weighting(self):
        st = H.analyze(HLO_SAMPLE)
        # dot: 2*8*128*128 flops, 10 trips
        assert st.flops == pytest.approx(10 * 2 * 8 * 128 * 128)
        # all-reduce in body: 2 * 8*128*4 bytes * 10 trips; all-gather once
        ar = 10 * 2 * 8 * 128 * 4
        ag = 128 * 256 * 4
        assert st.coll_bytes["all-reduce"] == pytest.approx(ar)
        assert st.coll_bytes["all-gather"] == pytest.approx(ag)

    def test_kernel_scope_excluded_from_bytes(self):
        txt = HLO_SAMPLE.replace(
            "rhs_contracting_dims={0}",
            'rhs_contracting_dims={0}, metadata={op_name="jit(f)/fusedkernel_flash_attention/dot"}',
        )
        st0 = H.analyze(HLO_SAMPLE)
        st1 = H.analyze(txt)
        assert st1.hbm_bytes < st0.hbm_bytes
        assert st1.flops == st0.flops  # flops still counted


@pytest.mark.slow
def test_small_mesh_dryrun_lowering(mesh):
    """End-to-end lowering of a reduced arch on a real (1,1) mesh: the same
    build path the production dry-run uses."""
    from repro.common.config import InputShape
    from repro.launch.workload import build_steps
    from repro.configs import get_config

    cfg = get_config("yi-34b").reduced()
    shape = InputShape("tiny_train", 32, 4, "train")
    built = build_steps(cfg, shape, mesh=mesh)
    with mesh:
        lowered = jax.jit(built["step"], in_shardings=built["arg_shardings"],
                          out_shardings=built["out_shardings"]).lower(*built["arg_specs"])
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
    stats = H.analyze(compiled.as_text())
    assert stats.flops > 0


def test_tpu_shardable_cfg_padding():
    from repro.launch.workload import tpu_shardable_cfg
    from repro.configs import get_config

    yi = tpu_shardable_cfg(get_config("yi-34b"), 16)
    assert yi.n_heads == 64 and yi.n_kv_heads == 8 and yi.head_dim == 128
    wh = tpu_shardable_cfg(get_config("whisper-large-v3"), 16)
    assert wh.n_heads == 32 and wh.n_kv_heads == 32
    mb = tpu_shardable_cfg(get_config("mamba2-130m"), 16)
    assert mb.ssm_n_heads == 32
    ok = tpu_shardable_cfg(get_config("kimi-k2-1t-a32b"), 16)
    assert ok.n_heads == 64 and ok.n_kv_heads == 8  # unchanged
