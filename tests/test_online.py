"""ProD-O (online remaining-length) unit tests."""

import jax
import numpy as np
import pytest

from repro.common.config import PredictorConfig
from repro.core import online


def _fake_trajectories(B=12, T=30, d=16, seed=0):
    """Synthetic states whose features encode the remaining length."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(8, T, size=B)
    hidden = np.zeros((B, T, d), np.float32)
    valid = np.zeros((B, T), bool)
    for b in range(B):
        for t in range(int(lengths[b])):
            rem = lengths[b] - (t + 1)
            hidden[b, t, 0] = rem / T + 0.02 * rng.standard_normal()
            hidden[b, t, 1:] = 0.1 * rng.standard_normal(d - 1)
            valid[b, t] = True
    return hidden, valid, lengths


def test_build_online_dataset_alignment():
    hidden, valid, lengths = _fake_trajectories()
    phi, rem, ts, b = online.build_online_dataset(hidden, valid, lengths)
    assert phi.shape[0] == rem.shape[0] == ts.shape[0] == b.shape[0]
    assert phi.shape[0] == int(sum(lengths))  # one state per generated token
    # remaining at the last step of each trajectory is 0
    for bb in range(len(lengths)):
        m = b == bb
        assert rem[m].min() == 0 and rem[m].max() == lengths[bb] - 1
        np.testing.assert_array_equal(np.sort(ts[m]), np.arange(1, lengths[bb] + 1))


def test_online_head_learns_remaining():
    hidden, valid, lengths = _fake_trajectories(B=24, T=40)
    phi, rem, ts, b = online.build_online_dataset(hidden, valid, lengths)
    pcfg = PredictorConfig(n_bins=16, bin_max=float(rem.max() + 2), epochs=25,
                           batch_size=64)
    head = online.train_online_predictor(jax.random.PRNGKey(0), phi, rem, pcfg)
    pred = np.asarray(head.predict(phi))
    mae = float(np.mean(np.abs(pred - rem)))
    const = float(np.mean(np.abs(rem - np.median(rem))))
    assert mae < 0.6 * const, (mae, const)


def test_evaluate_by_progress_buckets():
    hidden, valid, lengths = _fake_trajectories(B=16, T=30)
    phi, rem, ts, b = online.build_online_dataset(hidden, valid, lengths)
    pcfg = PredictorConfig(n_bins=16, bin_max=float(rem.max() + 2), epochs=10,
                           batch_size=64)
    head = online.train_online_predictor(jax.random.PRNGKey(0), phi, rem, pcfg)
    rep = online.evaluate_by_progress(head, phi, rem, ts,
                                      static_total_pred=np.full(len(rem), 20.0))
    assert rep["online"] and rep["static"]
    assert sum(rep["count"].values()) == len(rem)
