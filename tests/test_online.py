"""ProD-O (online remaining-length) unit tests."""

import jax
import numpy as np
import pytest

from repro.common.config import PredictorConfig
from repro.core import online


def _fake_trajectories(B=12, T=30, d=16, seed=0):
    """Synthetic states whose features encode the remaining length."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(8, T, size=B)
    hidden = np.zeros((B, T, d), np.float32)
    valid = np.zeros((B, T), bool)
    for b in range(B):
        for t in range(int(lengths[b])):
            rem = lengths[b] - (t + 1)
            hidden[b, t, 0] = rem / T + 0.02 * rng.standard_normal()
            hidden[b, t, 1:] = 0.1 * rng.standard_normal(d - 1)
            valid[b, t] = True
    return hidden, valid, lengths


def test_build_online_dataset_alignment():
    hidden, valid, lengths = _fake_trajectories()
    phi, rem, ts, b = online.build_online_dataset(hidden, valid, lengths)
    assert phi.shape[0] == rem.shape[0] == ts.shape[0] == b.shape[0]
    assert phi.shape[0] == int(sum(lengths))  # one state per generated token
    # remaining at the last step of each trajectory is 0
    for bb in range(len(lengths)):
        m = b == bb
        assert rem[m].min() == 0 and rem[m].max() == lengths[bb] - 1
        np.testing.assert_array_equal(np.sort(ts[m]), np.arange(1, lengths[bb] + 1))


def test_online_head_learns_remaining():
    hidden, valid, lengths = _fake_trajectories(B=24, T=40)
    phi, rem, ts, b = online.build_online_dataset(hidden, valid, lengths)
    pcfg = PredictorConfig(n_bins=16, bin_max=float(rem.max() + 2), epochs=25,
                           batch_size=64)
    head = online.train_online_predictor(jax.random.PRNGKey(0), phi, rem, pcfg)
    pred = np.asarray(head.predict(phi))
    mae = float(np.mean(np.abs(pred - rem)))
    const = float(np.mean(np.abs(rem - np.median(rem))))
    assert mae < 0.6 * const, (mae, const)


def test_evaluate_by_progress_buckets():
    hidden, valid, lengths = _fake_trajectories(B=16, T=30)
    phi, rem, ts, b = online.build_online_dataset(hidden, valid, lengths)
    pcfg = PredictorConfig(n_bins=16, bin_max=float(rem.max() + 2), epochs=10,
                           batch_size=64)
    head = online.train_online_predictor(jax.random.PRNGKey(0), phi, rem, pcfg)
    rep = online.evaluate_by_progress(head, phi, rem, ts,
                                      static_total_pred=np.full(len(rem), 20.0))
    assert rep["online"] and rep["static"]
    assert sum(rep["count"].values()) == len(rem)


# ---------------------------------------------------------------------------
# PosteriorRefiner edge cases (mid-flight refinement)
# ---------------------------------------------------------------------------


EDGES = np.array([0.0, 8.0, 32.0, 128.0, 512.0])


@pytest.mark.parametrize("t", [0, 0.0])
def test_refiner_t_zero_is_identity(t):
    """At t = 0 truncation removes nothing: the conditional equals the
    dispatch histogram exactly (edge[0] = 0, so no partial first bin)."""
    rz = online.PosteriorRefiner(EDGES)
    p = np.array([0.4, 0.3, 0.2, 0.1])
    np.testing.assert_allclose(rz.condition(p, t), p, atol=1e-15)
    assert rz.survivor(p, t) == pytest.approx(1.0)


@pytest.mark.parametrize("t", [512.0, 513.0, 1e6])
def test_refiner_past_support_is_point_mass_at_cap(t):
    """t at/past the last edge: an explicit degenerate point mass at the
    cap — finite quantiles of max(cap, t+1), never a NaN-prone renorm."""
    rz = online.PosteriorRefiner(EDGES)
    p = np.array([0.4, 0.3, 0.2, 0.1])
    cond = rz.condition(p, t)
    assert not np.any(np.isnan(cond))
    assert cond[-1] == 1.0 and np.all(cond[:-1] == 0.0)
    qs = rz.quantiles(p, t, (0.1, 0.5, 0.99))
    assert np.all(qs == max(512.0, t + 1.0))
    assert np.all(np.isfinite(qs))


def test_refiner_past_last_nonzero_bin():
    """t beyond every bin that carries mass (but inside the support) is
    degenerate too — zero survivor mass must not divide by ~0."""
    rz = online.PosteriorRefiner(EDGES)
    p = np.array([0.5, 0.5, 0.0, 0.0])      # support ends at 32
    cond = rz.condition(p, 200.0)
    assert not np.any(np.isnan(cond))
    assert cond[-1] == 1.0
    assert rz.quantile(p, 200.0, 0.5) == 512.0


def test_refiner_single_bin_histogram():
    """A one-bin distribution (and a one-bin edge array) stays proper and
    interpolates within the bin."""
    rz = online.PosteriorRefiner(np.array([0.0, 64.0]))
    p = np.array([1.0])
    np.testing.assert_allclose(rz.condition(p, 16.0), [1.0])
    q = rz.quantile(p, 16.0, 0.5)
    assert 16.0 <= q <= 64.0
    # survivor shrinks linearly inside the uniform bin
    assert rz.survivor(p, 32.0) == pytest.approx(0.5)


def test_refiner_quantiles_respect_cap_override():
    """A cap above the last edge (max_seq_len > bin_max) widens the
    degenerate clamp, and quantiles never exceed max(cap, t+1)."""
    rz = online.PosteriorRefiner(EDGES, cap=1024.0)
    p = np.array([0.4, 0.3, 0.2, 0.1])
    assert rz.quantile(p, 600.0, 0.5) == 1024.0
    assert rz.quantile(p, 2000.0, 0.5) == 2001.0
    assert rz.quantile(p, 4.0, 0.99) <= 1024.0


def test_refiner_mass_conservation_vs_survivor():
    """The normalized conditional times the survivor recovers the truncated
    mass: condition() and survivor() agree on the same uniform-in-bin
    truncation model."""
    rz = online.PosteriorRefiner(EDGES)
    p = np.array([0.25, 0.25, 0.25, 0.25])
    for t in (4.0, 20.0, 100.0, 300.0):
        s = rz.survivor(p, t)
        np.testing.assert_allclose(rz.condition(p, t) * s,
                                   rz._mass(p, t), atol=1e-12)
        assert 0.0 < s < 1.0


def test_hazard_table_row_lookup_floors():
    """Grid lookup floors to the last grid point ≤ t and clamps at both
    ends — refine ticks between grid points reuse the earlier row."""
    hz = online.HazardTable(ts=np.array([0.0, 32.0, 128.0]),
                            probs=np.eye(3), prior=np.full(3, 1 / 3))
    np.testing.assert_array_equal(hz.row(-5.0), hz.probs[0])
    np.testing.assert_array_equal(hz.row(0.0), hz.probs[0])
    np.testing.assert_array_equal(hz.row(31.9), hz.probs[0])
    np.testing.assert_array_equal(hz.row(32.0), hz.probs[1])
    np.testing.assert_array_equal(hz.row(1e9), hz.probs[2])
