"""Theorem 1 / Lemma 3 validation on the linear surrogate (paper App. B)."""

import numpy as np
import pytest

from repro.core import theory as TH
from repro.data.synthetic import surrogate_linear_data


def test_lemma3_median_moment_bound():
    """E|median_r|^{1+eps} <= 2 E|X|^{1+eps} for symmetric heavy-tailed X."""
    for eps in (0.3, 0.5, 1.0):
        df = 1 + 2 * eps
        base, med = TH.lemma3_moment(
            lambda rng, shape: rng.standard_t(df, size=shape), r=16, eps=eps,
            n_trials=40000)
        assert med <= 2.0 * base * 1.05  # small MC slack
        assert med < base  # median is strictly better for heavy tails


def test_failure_prob_decays_exponentially():
    N = 1000
    probs = [TH.failure_prob(N, r) for r in (8, 16, 32, 64, 128)]
    assert all(a > b for a, b in zip(probs, probs[1:]))
    r_star = TH.r_required(N, delta=0.05)
    assert TH.failure_prob(N, r_star) <= 0.05 + 1e-9


def test_ridge_closed_form():
    rng = np.random.default_rng(0)
    phi = rng.standard_normal((50, 4))
    y = phi @ np.array([1.0, -2.0, 0.5, 0.0]) + 0.01 * rng.standard_normal(50)
    fit = TH.ridge_fit(phi, y, lam=1e-6)
    np.testing.assert_allclose(fit.theta, [1.0, -2.0, 0.5, 0.0], atol=0.02)


def test_median_labels_reduce_estimation_error():
    """The operational content of Thm. 1: median-of-r labels give a smaller
    ridge estimation error than single-draw labels under heavy-tailed noise."""
    phi, eta, theta = surrogate_linear_data(n=800, d=8, eps=0.5, v=1.0, r=16,
                                            seed=1)
    y_true = phi @ theta
    fit_single = TH.ridge_fit(phi, y_true + eta[:, 0], lam=1.0)
    fit_median = TH.ridge_fit(phi, y_true + np.median(eta, axis=1), lam=1.0)
    err_single = np.linalg.norm(fit_single.theta - theta)
    err_median = np.linalg.norm(fit_median.theta - theta)
    assert err_median < err_single


def test_theorem1_bound_holds_empirically():
    """|phi^T theta* - phi^T theta_hat| <= beta_N ||phi||_{V_N^{-1}} with
    coverage >= 1 - 2 delta when r >= r_required (the bound is loose, so
    coverage should in fact be ~1)."""
    N, d, eps, v, S = 600, 6, 0.5, 1.0, 1.0
    delta = 0.1
    r = TH.r_required(N, delta)
    phi, eta, theta = surrogate_linear_data(n=N, d=d, eps=eps, v=v, r=r, seed=2)
    labels = phi @ theta + np.median(eta[:, :r], axis=1)
    lam = 1.0
    fit = TH.ridge_fit(phi, labels, lam=lam)
    beta = TH.theorem1_beta(N, d, v, eps, delta, lam, S)
    cov = TH.empirical_coverage(fit, phi, phi @ theta, beta)
    assert cov >= 1 - 2 * delta


def test_beta_grows_sublinearly_in_N():
    betas = [TH.theorem1_beta(N, 8, 1.0, 0.5, 0.05, 1.0, 1.0)
             for N in (100, 1000, 10000)]
    # N^{(1-eps)/(2(1+eps))} = N^{1/6} growth modulo logs: much slower than N
    assert betas[2] / betas[0] < 100 ** 0.5
