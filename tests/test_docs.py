"""Docs can't silently rot: every ```python fence in docs/*.md + README.md
must at least be valid Python (compile check), and every intra-repo link or
backticked file path must point at something that exists."""

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.S)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backticked repo paths: `docs/serving.md`, `src/repro/core/`, `pytest.ini`…
TICKED_PATH_RE = re.compile(
    r"`([A-Za-z0-9_.][A-Za-z0-9_./-]*(?:\.(?:py|md|yml|yaml|txt|ini|json)|/))`"
)
# bases a relative path may be written against (docs shorthand like
# `serving/arrivals.py` for src/repro/serving/arrivals.py included)
BASES = (REPO, REPO / "docs", REPO / "src" / "repro")


def _fences(text):
    return FENCE_RE.findall(text)


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_python_fences_compile(doc):
    for lang, body in _fences(doc.read_text()):
        if lang == "python":
            compile(body, f"{doc.name}:fence", "exec")


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_intra_repo_links_resolve(doc):
    text = doc.read_text()
    # strip fences: code samples may show illustrative paths
    stripped = FENCE_RE.sub("", text)
    bad = []
    for target in LINK_RE.findall(stripped):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (doc.parent / rel).exists():
            bad.append(target)
    assert not bad, f"{doc.name}: broken relative links {bad}"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_backticked_paths_exist(doc):
    stripped = FENCE_RE.sub("", doc.read_text())
    bad = []
    for token in TICKED_PATH_RE.findall(stripped):
        if "*" in token or "{" in token:
            continue        # glob/brace shorthand like bench_*.py
        if not any((b / token).exists() for b in BASES):
            bad.append(token)
    assert not bad, f"{doc.name}: backticked paths not found in repo {bad}"


def test_docs_tree_exists():
    """The documented entry points stay present."""
    for name in ("architecture.md", "serving.md", "reproducing.md"):
        assert (REPO / "docs" / name).is_file(), name


def test_readme_points_at_docs():
    text = (REPO / "README.md").read_text()
    assert "docs/serving.md" in text
    assert "docs/reproducing.md" in text
