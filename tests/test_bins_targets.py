"""Unit + property tests for the paper-core bin grids, targets, and decoders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import bins as B
from repro.core import targets as T

settings.register_profile("ci", deadline=None, max_examples=16)
settings.load_profile("ci")


class TestBins:
    def test_linear_edges(self):
        e = B.linear_edges(4, 100.0)
        np.testing.assert_allclose(np.asarray(e), [0, 25, 50, 75, 100])

    def test_bin_index_bounds(self):
        e = B.linear_edges(8, 80.0)
        idx = B.bin_index(jnp.array([-5.0, 0.0, 10.0, 79.9, 80.0, 1e9]), e)
        assert int(idx.min()) >= 0 and int(idx.max()) <= 7
        assert int(idx[2]) == 1

    def test_log_edges_start_zero(self):
        e = B.log_edges(8, 1000.0)
        assert float(e[0]) == 0.0 and float(e[-1]) == pytest.approx(1000.0)

    # K drawn from a fixed grid (not integers(4, 64)): each distinct K is a
    # fresh XLA executable, so bounding the shapes keeps the sweep cheap
    # while bin_max still ranges continuously.
    @given(st.sampled_from((4, 7, 16, 33, 64)), st.floats(10.0, 1e5))
    def test_bin_index_roundtrip(self, K, bin_max):
        e = B.make_edges(K, bin_max)
        centers = B.bin_centers(e)
        idx = B.bin_index(centers, e)
        np.testing.assert_array_equal(np.asarray(idx), np.arange(K))

    def test_median_decode_exact_on_concentrated(self):
        e = B.linear_edges(10, 100.0)
        probs = jnp.zeros((1, 10)).at[0, 3].set(1.0)
        # whole mass in bin 3 -> median at the bin midpoint
        assert float(B.decode_median(probs, e)[0]) == pytest.approx(35.0)

    def test_median_decode_interpolation(self):
        e = B.linear_edges(2, 20.0)
        probs = jnp.array([[0.25, 0.75]])
        # cdf crosses 0.5 inside bin 1: t = (0.5-0.25)/0.75 -> 10 + t*10
        assert float(B.decode_median(probs, e)[0]) == pytest.approx(10 + 10 / 3, rel=1e-5)

    @given(st.lists(st.floats(0.01, 1.0), min_size=4, max_size=16))
    def test_median_decode_within_support(self, raw):
        p = np.asarray(raw, np.float64)
        p = p / p.sum()
        e = B.linear_edges(len(p), 128.0)
        m = float(B.decode_median(jnp.asarray(p)[None], e)[0])
        assert 0.0 <= m <= 128.0

    def test_median_less_tail_sensitive_than_mean(self):
        """The paper's §2.4 argument: median decode is robust to tail mass."""
        e = B.linear_edges(10, 1000.0)
        base = jnp.zeros(10).at[1].set(0.9).at[2].set(0.1)
        tail = jnp.zeros(10).at[1].set(0.9).at[9].set(0.1)
        dm = abs(float(B.decode_median(tail[None], e)[0]) -
                 float(B.decode_median(base[None], e)[0]))
        dmean = abs(float(B.decode_mean(tail[None], e)[0]) -
                    float(B.decode_mean(base[None], e)[0]))
        assert dm < dmean


class TestTargets:
    def test_median_target_onehot(self):
        e = B.linear_edges(8, 80.0)
        L = jnp.array([[10.0, 12.0, 11.0, 200.0]])  # median 11.5 -> bin 1
        y = T.median_target(L, e)
        assert y.shape == (1, 8)
        assert float(y.sum()) == 1.0 and int(jnp.argmax(y)) == 1

    def test_dist_target_is_histogram(self):
        e = B.linear_edges(4, 40.0)
        L = jnp.array([[5.0, 15.0, 15.0, 35.0]])
        p = T.dist_target(L, e)
        np.testing.assert_allclose(np.asarray(p[0]), [0.25, 0.5, 0.0, 0.25])

    # (r, K) both set shapes; a fixed grid + fewer examples bounds the
    # number of distinct compiled executables without narrowing the
    # covered range (1-sample and 64-bin corners stay in the pool).
    @settings(deadline=None, max_examples=10)
    @given(st.sampled_from((1, 2, 7, 32)), st.sampled_from((2, 16, 64)))
    def test_dist_target_normalized(self, r, K):
        rng = np.random.default_rng(0)
        L = jnp.asarray(rng.uniform(1, 500, size=(5, r)))
        e = B.linear_edges(K, 600.0)
        p = T.dist_target(L, e)
        np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)

    @given(st.integers(0, 400))
    def test_median_target_robust_to_tail_contamination(self, outlier_extra):
        """Property (Obs. 2): replacing a minority of samples with arbitrarily
        long generations does not move the median-target bin."""
        e = B.linear_edges(32, 1000.0)
        base = np.full(16, 100.0)
        contaminated = base.copy()
        contaminated[:7] = 900.0 + outlier_extra % 100  # minority
        y0 = T.median_target(jnp.asarray(base)[None], e)
        y1 = T.median_target(jnp.asarray(contaminated)[None], e)
        assert int(jnp.argmax(y0)) == int(jnp.argmax(y1))

    def test_mean_not_robust_same_contamination(self):
        base = np.full(16, 100.0)
        contaminated = base.copy()
        contaminated[:7] = 900.0
        assert abs(contaminated.mean() - base.mean()) > 300  # mean moves a lot

    def test_single_target_matches_sample(self):
        e = B.linear_edges(8, 80.0)
        L = jnp.array([[10.0, 75.0]])
        y0 = T.single_target(L, e, 0)
        y1 = T.single_target(L, e, 1)
        assert int(jnp.argmax(y0)) == 1 and int(jnp.argmax(y1)) == 7

    def test_build_target_dispatch(self):
        e = B.linear_edges(8, 80.0)
        L = jnp.asarray(np.random.default_rng(0).uniform(1, 79, (3, 16)))
        for kind in ("median", "dist", "single"):
            y = T.build_target(L, e, kind)
            assert y.shape == (3, 8)
        with pytest.raises(ValueError):
            T.build_target(L, e, "nope")
