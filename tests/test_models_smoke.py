"""Per-architecture smoke tests: REDUCED config (2 layers, d_model<=512,
<=4 experts), one forward/train step + prefill/decode coherence on CPU."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model_zoo import Runtime, build_model, last_token_hidden

RT = Runtime.local()


@functools.lru_cache(maxsize=None)
def _reduced_model(arch):
    """One build+init per arch, shared across this module's tests — param
    init was re-paid three times per arch and is pure given the fixed key."""
    cfg = get_config(arch).reduced().with_overrides(dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


@functools.lru_cache(maxsize=None)
def _jit_prefill(arch):
    """Jit-cached prefill per arch: XLA-compiling the 2-layer graph once is
    cheaper than eager op-by-op dispatch, and reuses across tests."""
    _, m, _ = _reduced_model(arch)
    return jax.jit(lambda p, b: m.prefill(p, b, RT))


@functools.lru_cache(maxsize=None)
def _jit_train_step(arch, B, S):
    """Shared jitted train-step per arch. The backward graph is the single
    biggest tier-1 compile cost (~6–15 s per arch at default settings), and
    this test only asserts loss/grad finiteness — so compile at XLA
    optimization level 0: ~2x faster to build, same graph semantics, and the
    cache keeps any future caller from re-paying it."""
    cfg, m, _ = _reduced_model(arch)
    batch = _batch_for(cfg, jax.random.PRNGKey(1), B, S)
    fn = jax.jit(jax.value_and_grad(lambda p: m.loss(p, batch, RT)[0]),
                 compiler_options={"xla_backend_optimization_level": "0"})
    return fn


def _batch_for(cfg, key, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 1, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        from repro.models.frontend import vlm_embeds
        emb, pos = vlm_embeds(key, cfg, B, S, n_patches=8)
        batch["embeds"] = emb
        batch["positions"] = pos
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg, m, params = _reduced_model(arch)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.family == "moe":
        assert cfg.n_experts <= 4
    B, S = 2, 16
    # one jitted value_and_grad: XLA-compiling the 2-layer graph is several
    # times cheaper than dispatching loss + grad op-by-op in eager mode
    loss, grads = _jit_train_step(arch, B, S)(params)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_prefill_shapes_and_phi(arch):
    cfg, m, params = _reduced_model(arch)
    B, S = 2, 12
    batch = _batch_for(cfg, jax.random.PRNGKey(1), B, S)
    logits, hidden, cache, aux = _jit_prefill(arch)(params, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert logits.shape == (B, S, cfg.vocab_size)
    phi = last_token_hidden(hidden, jnp.full((B,), S))
    assert phi.shape == (B, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(phi)))


@pytest.mark.parametrize("arch", ["yi-34b", "gemma3-27b", "mamba2-130m",
                                  "zamba2-1.2b", "kimi-k2-1t-a32b",
                                  "whisper-large-v3", "qwen2-vl-2b"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode_step(token S) == forward(S+1) at position S."""
    cfg, m, params = _reduced_model(arch)
    B, S = 2, 20
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S + 1), 1, cfg.vocab_size)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :S]}
    if cfg.family == "encdec":
        enc = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
        full["enc_embeds"] = enc
        pre["enc_embeds"] = enc
    if cfg.family == "vlm":
        from repro.models.rope import text_mrope_positions
        full["positions"] = text_mrope_positions(B, S + 1)
        pre["positions"] = text_mrope_positions(B, S)
    jp = _jit_prefill(arch)
    lg_full, _, _, _ = jp(params, full)
    _, _, cache, _ = jp(params, pre)

    def grow(x):
        if x.ndim >= 3 and x.shape[-3] == S:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, 8)
            return jnp.pad(x, pad)
        return x

    cache = jax.tree_util.tree_map(grow, cache)
    dbatch = {"tokens": toks[:, S], "pos": jnp.full((B,), S, jnp.int32),
              "lengths": jnp.full((B,), S + 1, jnp.int32)}
    lg_d, _, _ = m.decode_step(params, dbatch, cache, RT)
    scale = float(jnp.max(jnp.abs(lg_full[:, S]))) + 1.0
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_full[:, S]),
                               atol=2e-3 * scale, rtol=1e-3)


def test_moe_capacity_matches_dense_reference():
    from repro.models.layers import init_tree, mlp_apply
    from repro.models.moe import moe_apply, moe_spec
    cfg = get_config("qwen3-moe-235b-a22b").reduced().with_overrides(dtype="float32")
    p = init_tree(jax.random.PRNGKey(2), moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 7, cfg.d_model))
    out, aux = moe_apply(p, x, cfg, capacity_factor=0.0)  # full capacity
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    pr = jax.nn.softmax(logits, -1)
    tp, ti = jax.lax.top_k(pr, cfg.n_experts_per_token)
    cw = tp / tp.sum(-1, keepdims=True)
    want = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        ye = (jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])) @ p["w_down"][e]
        want += ye * jnp.where(ti == e, cw, 0).sum(-1)[:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_gemma_layer_plan_pattern():
    from repro.models.transformer import layer_plan
    cfg = get_config("gemma3-27b")
    plan = layer_plan(cfg)
    assert plan[0].kinds == ("local",) * 5 + ("full",)
    assert plan[0].n_blocks == 10
    assert plan[1].kinds == ("local", "local")
    total = sum(len(s.kinds) * s.n_blocks for s in plan)
    assert total == cfg.n_layers


def test_zamba_hybrid_plan():
    from repro.models.transformer import layer_plan
    cfg = get_config("zamba2-1.2b")
    plan = layer_plan(cfg)
    total_ssm = sum(s.kinds.count("ssm") * s.n_blocks for s in plan)
    assert total_ssm == cfg.n_layers
    assert plan[0].kinds[0] == "shared_attn"
