"""Correctness of the §Perf optimization variants (they must not change
semantics beyond controlled quantization error)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model_zoo import Runtime, build_model


def test_int8_kv_single_step_error_small():
    """Quantized-cache decode vs fp decode with the SAME context: the isolated
    int8 error on logits stays below ~2% of the logit scale."""
    cfg = get_config("yi-34b").reduced().with_overrides(dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 1,
                              cfg.vocab_size)
    rt = Runtime.local()
    cache_fp = m.init_cache(B, S + 2)
    cache_q = m.init_cache(B, S + 2, kv_quant=True)
    # jit the decode step (one compile per cache dtype) — the numerics under
    # test are identical, but 12 eager decode graphs cost ~25s on CPU
    step = jax.jit(lambda p, db, c: m.decode_step(p, db, c, rt))
    # build BOTH caches from the fp trajectory (feed the same tokens; the
    # quantized model's divergence is reset by re-feeding ground-truth tokens)
    for t in range(S):
        db = {"tokens": toks[:, t], "pos": jnp.full((B,), t, jnp.int32),
              "lengths": jnp.full((B,), t + 1, jnp.int32)}
        lf, _, cache_fp = step(params, db, cache_fp)
        lq, _, cache_q = step(params, db, cache_q)
    scale = float(jnp.max(jnp.abs(lf)))
    # average error across the trajectory must stay bounded (untrained nets
    # are chaotic, so compare medians not maxima)
    err = float(jnp.median(jnp.abs(lf - lq)))
    assert err < 0.1 * scale + 0.05, (err, scale)


def test_int8_cache_memory_is_half():
    cfg = get_config("yi-34b").reduced()
    m = build_model(cfg)
    fp = m.cache_shapes(4, 64)
    q8 = m.cache_shapes(4, 64, kv_quant=True)
    size = lambda tree: sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree_util.tree_leaves(tree))
    assert size(q8) < 0.65 * size(fp)  # int8 + scales ≈ 0.53×


MOE_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.layers import init_tree
    from repro.models.moe import moe_apply, moe_spec
    cfg = get_config("qwen3-moe-235b-a22b").reduced().with_overrides(
        dtype="float32", d_model=64, moe_d_ff=32)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    p = init_tree(jax.random.PRNGKey(0), moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    with mesh:
        outs = {}
        for mode in ("gather", "partial"):
            f = jax.jit(lambda p, x, m=mode: moe_apply(
                p, x, cfg, mesh=mesh, capacity_factor=0.0, cap_slack=1.0,
                fsdp_mode=m)[0])
            outs[mode] = np.asarray(f(p, x))
    err = np.max(np.abs(outs["gather"] - outs["partial"]))
    print("ERR", err)
    assert err < 1e-3, err
""")


@pytest.mark.slow
def test_moe_partial_matches_gather_on_4dev_mesh():
    """The partial-sum FSDP mode must equal the weight-gather mode bit-for-bit
    (up to fp reassociation). Runs in a subprocess with 4 host devices."""
    r = subprocess.run(
        [sys.executable, "-c", MOE_EQUIV_SCRIPT],
        capture_output=True, text=True, timeout=480,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ERR" in r.stdout


def test_causal_skip_equals_full_blocked_attention():
    from repro.models.attention import blocked_attention
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    a = blocked_attention(q, k, v, block_q=16, block_kv=16, causal_skip=False)
    b = blocked_attention(q, k, v, block_q=16, block_kv=16, causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
