"""Optimizers, schedules, trainer loop, checkpointing, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig
from repro.configs import get_config
from repro.data.pipeline import batch_iterator, make_lm_dataset
from repro.data.lengths import LengthLaw, sample_lengths, sample_prompt_latents
from repro.models.model_zoo import Runtime, build_model
from repro.training import optim
from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.trainer import train_loop


class TestOptim:
    def _quad(self, opt, steps=200):
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for i in range(steps):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params,
                                       jnp.asarray(i, jnp.float32))
        return float(jnp.sum(jnp.abs(params["w"])))

    def test_adamw_converges(self):
        cfg = TrainConfig(lr=0.1, schedule="constant", warmup_steps=1,
                          weight_decay=0.0)
        assert self._quad(optim.adamw(cfg)) < 0.05

    def test_adafactor_converges(self):
        cfg = TrainConfig(optimizer="adafactor", lr=0.1, schedule="constant",
                          warmup_steps=1)
        assert self._quad(optim.adafactor(cfg)) < 0.1

    def test_adafactor_state_is_factored(self):
        cfg = TrainConfig(optimizer="adafactor")
        opt = optim.adafactor(cfg)
        p = {"w": jnp.zeros((64, 32))}
        st = opt.init(p)
        assert st["w"]["vr"].shape == (64,) and st["w"]["vc"].shape == (32,)

    def test_wsd_schedule_phases(self):
        cfg = TrainConfig(schedule="wsd", lr=1.0, warmup_steps=10,
                          stable_steps=50, decay_steps=100)
        s = optim.lr_schedule(cfg)
        assert float(s(5)) == pytest.approx(0.5)        # warmup
        assert float(s(30)) == pytest.approx(1.0)       # stable plateau
        assert float(s(99)) < 0.3                       # decay
        assert float(s(80)) > float(s(95))

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = optim.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


class TestTrainer:
    def test_tiny_lm_loss_decreases(self):
        cfg = get_config("tiny-lm").with_overrides(dtype="float32", n_layers=2)
        model = build_model(cfg)
        tcfg = TrainConfig(lr=1e-2, schedule="constant", warmup_steps=2, seed=0)
        ds = make_lm_dataset(128, 32, seed=0)
        ds.tokens = np.minimum(ds.tokens, cfg.vocab_size - 1)
        it = batch_iterator(ds, 8, seed=0)
        # capture first/last loss
        from repro.training.trainer import init_state, make_train_step
        state = init_state(model, jax.random.PRNGKey(0), tcfg)
        step = jax.jit(make_train_step(model, tcfg, Runtime.local()))
        tree = state.tree()
        losses = []
        for i in range(40):
            tree, m = step(tree, next(it))
            losses.append(float(m["loss"]))
        assert min(losses[-5:]) < losses[0] - 0.3, losses[::10]

    def test_microbatch_equivalent_direction(self):
        cfg = get_config("tiny-lm").with_overrides(dtype="float32", n_layers=1)
        model = build_model(cfg)
        ds = make_lm_dataset(32, 32, seed=1)
        ds.tokens = np.minimum(ds.tokens, cfg.vocab_size - 1)
        batch = {"tokens": jnp.asarray(ds.tokens[:8]),
                 "loss_mask": jnp.asarray(ds.loss_mask[:8])}
        from repro.training.trainer import init_state, make_train_step
        outs = {}
        for mb in (1, 2):
            tcfg = TrainConfig(lr=1e-2, warmup_steps=1, microbatch=mb, seed=0)
            st = init_state(model, jax.random.PRNGKey(0), tcfg)
            step = jax.jit(make_train_step(model, tcfg, Runtime.local()))
            tree, m = step(st.tree(), batch)
            outs[mb] = float(m["loss"])
        assert outs[1] == pytest.approx(outs[2], rel=1e-4)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        path = save_checkpoint(str(tmp_path), tree, step=7)
        back = restore_checkpoint(path, tree)
        np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))

    def test_mismatch_raises(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        path = save_checkpoint(str(tmp_path), tree, step=1)
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"a": jnp.zeros((2,)), "b": jnp.zeros(1)})


class TestData:
    def test_length_law_median_matches_scale(self):
        rng = np.random.default_rng(0)
        law = LengthLaw(median_scale=200, median_spread=0.0, sigma_body=0.1,
                        tail_weight=0.02, tail_alpha=2.5)
        lat = sample_prompt_latents(rng, law, 400)
        L = sample_lengths(rng, lat, 33, law)
        med = np.median(L)
        assert 160 < med < 250

    def test_heavy_tail_present(self):
        rng = np.random.default_rng(1)
        law = LengthLaw(median_scale=100, median_spread=0.0, sigma_body=0.15,
                        tail_weight=0.06, tail_alpha=1.8)
        lat = sample_prompt_latents(rng, law, 200)
        L = sample_lengths(rng, lat, 100, law)
        ratio = L.max(axis=1) / np.median(L, axis=1)
        # some prompts show the paper's 2-4x max/median signature
        assert np.quantile(ratio, 0.9) > 1.8

    def test_batch_iterator_shapes_and_determinism(self):
        ds = make_lm_dataset(64, 32, seed=0)
        a = next(batch_iterator(ds, 16, seed=5))
        b = next(batch_iterator(ds, 16, seed=5))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (16, 32)
        assert set(np.unique(a["loss_mask"])) <= {0, 1}
