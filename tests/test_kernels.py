"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 33, 4, 4, 32),    # MHA, ragged seq
    (2, 64, 8, 2, 64),    # GQA
    (1, 96, 4, 1, 16),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 17), (False, 0)])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_kv=32, impl="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,Sc,H,KV,hd", [(2, 100, 8, 2, 64), (1, 40, 4, 4, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, Sc, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sc, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sc, KV, hd), dtype)
    lens = jnp.asarray(np.random.default_rng(0).integers(1, Sc + 1, B), jnp.int32)
    out = ops.decode_attention(q, k, v, lens, block_kv=32, impl="interpret")
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 53, 3, 8, 16, 16),
    (1, 64, 2, 4, 8, 32),
    (1, 17, 4, 16, 32, 8),
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -dt * jnp.exp(0.3 * jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y, h = ops.ssd_scan(x, dt, a, Bm, Cm, chunk=chunk, impl="interpret")
    y2, h2 = ref.ssd_scan_ref(x, dt, a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h2), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,d,hid,K", [(7, 32, 16, 8), (33, 96, 64, 32)])
def test_prod_head_sweep(B, d, hid, K):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    phi = jax.random.normal(ks[0], (B, d))
    w1 = jax.random.normal(ks[1], (d, hid)) * 0.2
    b1 = jax.random.normal(ks[2], (hid,)) * 0.01
    w2 = jax.random.normal(ks[3], (hid, K)) * 0.2
    b2 = jnp.zeros(K)
    edges = jnp.linspace(0.0, 512.0, K + 1)
    p1, m1 = ops.prod_head(phi, w1, b1, w2, b2, edges, block_b=8, impl="interpret")
    p2, m2 = ref.prod_head_ref(phi, w1, b1, w2, b2, edges)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-4, atol=1e-3)


def test_prod_head_median_consistent_with_bins_decoder():
    """Kernel median decode == core.bins.decode_median on the same probs."""
    from repro.core import bins as Bn
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, d, hid, K = 16, 24, 16, 12
    phi = jax.random.normal(ks[0], (B, d))
    w1 = jax.random.normal(ks[1], (d, hid)) * 0.3
    w2 = jax.random.normal(ks[2], (hid, K)) * 0.3
    edges = jnp.linspace(0.0, 120.0, K + 1)
    probs, med = ref.prod_head_ref(phi, w1, jnp.zeros(hid), w2, jnp.zeros(K), edges)
    np.testing.assert_allclose(np.asarray(med),
                               np.asarray(Bn.decode_median(probs, edges)),
                               rtol=1e-5, atol=1e-4)
