"""Integration tests for predictor training, baselines, and the paper's
qualitative claims on a small calibrated scenario."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PredictorConfig
from repro.core import bins as B
from repro.core import targets as T
from repro.core.baselines import METHODS, run_method
from repro.core.metrics import mae, noise_radius
from repro.core.predictor import train_predictor
from repro.data import make_scenario


@pytest.fixture(scope="session")
def scenario():
    return make_scenario("qwen", "math", n_train=500, n_test=250, seed=3)


@pytest.fixture(scope="session")
def pcfg(scenario):
    bm = float(np.quantile(scenario.len_train, 0.999) * 1.3)
    # hidden=256 halves head-training time; every assertion here is relative
    # (method vs method), so the paper-structure checks are unaffected
    return PredictorConfig(n_bins=48, bin_max=bm, epochs=15, hidden=256)


@pytest.fixture(scope="session")
def median_head(scenario, pcfg):
    """One trained ProD-M (median-target) head shared by every test that
    needs a trained predictor — retraining per test dominated tier-1 time."""
    edges = B.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = T.median_target(jnp.asarray(scenario.len_train, jnp.float32), edges)
    return train_predictor(jax.random.PRNGKey(0),
                           jnp.asarray(scenario.phi_train["last"]), tgt,
                           pcfg, edges)


@pytest.fixture(scope="session")
def dist_head(scenario, pcfg):
    """One trained ProD-D (distributional) head, shared likewise."""
    edges = B.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = T.dist_target(jnp.asarray(scenario.len_train, jnp.float32), edges)
    return train_predictor(jax.random.PRNGKey(0),
                           jnp.asarray(scenario.phi_train["last"]), tgt,
                           pcfg, edges)


def test_predictor_learns(scenario, pcfg, median_head):
    pred = median_head.predict(jnp.asarray(scenario.phi_test["last"]))
    med = T.sample_median(jnp.asarray(scenario.len_test, jnp.float32))
    m = mae(pred, med)
    const = mae(jnp.full_like(med, float(jnp.median(med))), med)
    assert m < 0.9 * const, f"predictor ({m:.1f}) should beat constant ({const:.1f})"


def test_predictor_quantiles_monotone(scenario, dist_head):
    phi = jnp.asarray(scenario.phi_test["last"][:32])
    q50 = np.asarray(dist_head.quantile(phi, 0.5))
    q90 = np.asarray(dist_head.quantile(phi, 0.9))
    assert (q90 >= q50 - 1e-6).all()


def test_prod_m_beats_single_supervision(scenario, pcfg):
    """Tables 1 vs 2: repeated-sampling median supervision beats one-shot."""
    k = jax.random.PRNGKey(1)
    rep = run_method(k, scenario, "prod_m", pcfg, supervision="repeat")
    single = run_method(k, scenario, "prod_m", pcfg, supervision="single",
                        eval_target="median")
    assert rep.test_mae < single.test_mae


def test_prod_d_single_sample_raises(scenario, pcfg):
    with pytest.raises(ValueError):
        run_method(jax.random.PRNGKey(0), scenario, "prod_d", pcfg,
                   supervision="single")


def test_method_ordering_matches_paper(scenario, pcfg):
    """Table 1 qualitative structure: ProD variants beat TRAIL-last; the
    last-token view beats the proxy and entropy views; everything beats the
    constant."""
    k = jax.random.PRNGKey(2)
    # train only the methods the assertions below compare (s3/trail_mean are
    # covered by their own tests); keep fold_in indices = METHODS positions
    # so each method's result is identical to the full sweep's. hidden=96
    # (vs the shared fixture's 256) roughly halves the 8 head trainings this
    # test pays for — every assertion is method-vs-method at identical dims,
    # so the paper-structure claims are unchanged
    ocfg = dataclasses.replace(pcfg, hidden=96)
    needed = ("constant_median", "trail_last", "egtp", "prod_m", "prod_d")
    res = {m: run_method(jax.random.fold_in(k, METHODS.index(m)),
                         scenario, m, ocfg) for m in needed}
    assert res["prod_d"].test_mae < res["trail_last"].test_mae
    # the paper's ProD-M vs TRAIL-last gap is ~5%; allow small-sample noise
    assert res["prod_m"].test_mae < res["trail_last"].test_mae * 1.05
    assert res["trail_last"].test_mae < res["constant_median"].test_mae
    assert res["trail_last"].test_mae < res["egtp"].test_mae


def test_noise_radius_sane(scenario):
    nr = noise_radius(jnp.asarray(scenario.len_test))
    # qwen/math calibration target ~33 tokens (Table 1 noise radius)
    assert 15 < nr < 70


def test_constant_median_mae_matches_definition(scenario, pcfg):
    res = run_method(jax.random.PRNGKey(0), scenario, "constant_median", pcfg)
    med_tr = float(np.median(np.median(scenario.len_train, axis=1)))
    med_te = np.median(scenario.len_test, axis=1)
    want = float(np.mean(np.abs(med_te - med_tr)))
    assert res.test_mae == pytest.approx(want, rel=1e-3)


def test_predictor_checkpoint_roundtrip(tmp_path, scenario, pcfg, median_head):
    """LengthPredictor params survive checkpointing (serving restarts)."""
    from repro.core.predictor import LengthPredictor
    from repro.training.checkpoint import restore_checkpoint, save_checkpoint

    p = median_head
    path = save_checkpoint(str(tmp_path), {"head": p.params, "edges": p.edges})
    back = restore_checkpoint(path, {"head": p.params, "edges": p.edges})
    p2 = LengthPredictor(params=back["head"], edges=back["edges"], pcfg=pcfg)
    phi = jnp.asarray(scenario.phi_test["last"][:32])
    np.testing.assert_allclose(np.asarray(p.predict(phi)),
                               np.asarray(p2.predict(phi)), rtol=1e-6)
