"""Keep-pages preemption (paged KV) regression suite.

Covers the partial-reservation-handoff tentpole: ``Policy.preempt_mode``
("recompute" vs "keep"), delta-only resume reservations, skipped prefill
recompute, page handoff under work stealing, the held-pages stall breaker,
page-size sweeps — and the cluster-level request-conservation invariant
``submitted == done + timed_out + rejected + dropped`` that the drop paths
must uphold. Every new path is asserted bit-identical between the per-slot
reference and the vectorized event-leap engines.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.serving.adaptation import AdmissionController
from repro.serving.arrivals import LatentOracle, TraceConfig, make_trace
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.request import Request
from repro.serving.scheduler import Policy

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")


def _trace(n=250, pattern="bursty", rate=1.2, seed=7, **kw):
    kw.setdefault("max_seq_len", 512)
    kw.setdefault("model", "mix")
    kw.setdefault("scenario", "mix")
    return make_trace(TraceConfig(n_requests=n, pattern=pattern, rate=rate,
                                  seed=seed, **kw))


def _pol(mode="keep", order="srtf_pred"):
    return Policy(order, "quantile", quantile=0.9, max_seq_len=512,
                  preempt=True, preempt_mode=mode)


def _engine_rows(pol, spec, reqs, **run_kw):
    rows = {}
    for vec in (True, False):
        eng = SimEngine(policy=pol, predictor=LatentOracle(), vectorized=vec,
                        spec=spec)
        strow = eng.run(reqs, **run_kw).row()
        fin = sorted((r.rid, r.t_start, r.t_finish) for r in eng.done)
        assert eng.kv.reserved_now == 0          # nothing leaked at the end
        assert eng._held_tokens == 0
        rows[vec] = (strow, fin)
    return rows


class TestVecRefKeepMode:
    """The event-leap fast path must stay bit-identical to the per-slot
    reference on every keep-pages path: shrink-and-hold preemption,
    delta-only resume, skipped prefill recompute, page handoff — across
    page sizes and heterogeneous specs."""

    @pytest.mark.parametrize("mode", ["recompute", "keep"])
    @pytest.mark.parametrize("page_size", [1, 16])
    def test_engine_vec_matches_ref(self, mode, page_size):
        reqs = _trace(slo_factor=6.0, slo_floor=100.0)
        spec = ReplicaSpec(6, 2 * (256 + 512) // 16 * 16, speed=2,
                           prefill_tokens_per_step=32, page_size=page_size)
        rows = _engine_rows(_pol(mode), spec, reqs, max_steps=500_000)
        assert rows[True] == rows[False]

    @pytest.mark.parametrize("mode", ["recompute", "keep"])
    def test_cluster_vec_matches_ref_with_steal_handoff(self, mode):
        """Stealing a keep-mode holder migrates its pages (export/adopt) at
        page-proportional cost — bit-exact in both decode paths."""
        reqs = _trace(n=400, rate=1.8, seed=11, slo_factor=8.0,
                      slo_floor=150.0)
        kv = 2 * (256 + 512) // 16 * 16
        specs = (ReplicaSpec(4, kv, speed=2, prefill_tokens_per_step=64,
                             page_size=16),
                 ReplicaSpec(2, kv // 2, speed=1, prefill_tokens_per_step=32,
                             page_size=16),
                 ReplicaSpec(6, 3 * kv // 2, speed=3, page_size=16))
        rows = {}
        for vec in (True, False):
            cl = Cluster(specs, _pol(mode), router="psq",
                         predictor=LatentOracle(), vectorized=vec,
                         rebalance_every=25, steal="quantile", steal_cost=1)
            strow = cl.run(reqs).row()
            fin = sorted((r.rid, r.t_start, r.t_finish)
                         for e in cl.engines for r in e.done)
            rows[vec] = (strow, fin)
        assert rows[True] == rows[False]
        assert rows[True][0]["stolen"] > 0
        assert rows[True][0]["steal_pages"] >= rows[True][0]["stolen"]
        assert rows[True][0]["steal_delay"] == rows[True][0]["steal_pages"]

    @given(st.integers(0, 10_000))
    def test_engine_vec_matches_ref_random_keep(self, seed):
        rng = np.random.default_rng(seed)
        spec = ReplicaSpec(int(rng.integers(2, 7)),
                           2 * (256 + 512) // 16 * 16,
                           speed=int(rng.integers(1, 4)),
                           prefill_tokens_per_step=int(rng.integers(0, 4))
                           * 32,
                           page_size=int(rng.choice([1, 4, 16, 64])))
        reqs = _trace(n=80, pattern="poisson", rate=0.8, seed=seed,
                      slo_factor=5.0, slo_floor=64.0)
        rows = _engine_rows(_pol("keep"), spec, reqs, max_steps=500_000)
        assert rows[True] == rows[False]

    def test_page_size_one_recompute_matches_legacy_golden(self):
        """page_size=1 + preempt_mode="recompute" is the seed configuration:
        the pre-paged golden rows (same-seed, both decode paths, zero paged
        columns) must reproduce exactly."""
        reqs = _trace(seed=21, slo_factor=6.0, slo_floor=100.0)
        spec = ReplicaSpec(6, 2 * (256 + 512), speed=2,
                           prefill_tokens_per_step=32, page_size=1)
        rows = _engine_rows(_pol("recompute"), spec, reqs, max_steps=500_000)
        assert rows[True] == rows[False]
        row = rows[True][0]
        assert row["page_size"] == 1
        assert row["frag_ratio"] == 0.0       # no page rounding
        assert row["held_peak"] == 0 and row["held_steps"] == 0.0
        assert row["held_releases"] == 0
        # the defaulted Policy/ReplicaSpec produce this row: rerunning with
        # the knobs left entirely unset must be bit-identical
        base_pol = Policy("srtf_pred", "quantile", quantile=0.9,
                          max_seq_len=512, preempt=True)
        base_spec = ReplicaSpec(6, 2 * (256 + 512), speed=2,
                                prefill_tokens_per_step=32)
        base = _engine_rows(base_pol, base_spec, reqs, max_steps=500_000)
        assert base == rows

    def test_keep_equals_recompute_when_preemption_off(self):
        """No regression when preemption is off: preempt_mode is inert."""
        reqs = _trace(seed=13, slo_factor=6.0, slo_floor=100.0)
        spec = ReplicaSpec(6, 2 * (256 + 512), speed=2,
                           prefill_tokens_per_step=32, page_size=16)
        rows = {}
        for mode in ("recompute", "keep"):
            pol = Policy("sjf_pred", "quantile", quantile=0.9,
                         max_seq_len=512, preempt=False, preempt_mode=mode)
            rows[mode] = _engine_rows(pol, spec, reqs, max_steps=500_000)
        assert rows["recompute"] == rows["keep"]


class TestKeepSemantics:
    def _one_preemption(self, mode, pts=8):
        """One long request preempted once by one short one, single slot —
        the minimal deterministic resume scenario."""
        pol = Policy("srtf_pred", "quantile", max_seq_len=4096, preempt=True,
                     preempt_mode=mode)
        spec = ReplicaSpec(1, 1024, prefill_tokens_per_step=pts, page_size=4)
        long = Request(rid=0, arrival=0.0, prompt_len=64, true_len=200,
                       predicted_len=200.0, reserve_len=220.0)
        short = Request(rid=1, arrival=20.0, prompt_len=8, true_len=20,
                        predicted_len=20.0, reserve_len=30.0)
        eng = SimEngine(policy=pol, spec=spec)
        st_row = eng.run([long, short])
        assert st_row.preemptions == 1
        return st_row, {r.rid: r for r in eng.done}

    def test_keep_resume_finishes_strictly_earlier(self):
        """The double-pay bugfix: a keep-mode resume skips the prefill
        recompute for its kept progress, so the preempted request finishes
        strictly earlier than the recompute-mode resume on the same seed —
        by at least the recompute charge it avoided."""
        rec_st, rec = self._one_preemption("recompute")
        keep_st, keep = self._one_preemption("keep")
        assert rec_st.recompute_ticks > 0
        assert keep_st.recompute_ticks == 0
        assert keep[0].t_finish < rec[0].t_finish
        assert rec[0].t_finish - keep[0].t_finish >= rec_st.recompute_ticks
        # the non-preempted request is untouched by the mode
        assert keep[1].t_finish == rec[1].t_finish

    def test_keep_resume_reserves_only_delta(self):
        """While the victim waits, its filled pages stay reserved (the
        memory cost keep mode pays) and router signals charge only the
        delta — no double count."""
        pol = _pol("keep")
        spec = ReplicaSpec(1, 2048, prefill_tokens_per_step=8, page_size=4)
        long = Request(rid=0, arrival=0.0, prompt_len=64, true_len=200,
                       predicted_len=200.0, reserve_len=220.0)
        short = Request(rid=1, arrival=20.0, prompt_len=8, true_len=50,
                        predicted_len=20.0, reserve_len=60.0)
        eng = SimEngine(policy=pol, spec=spec)
        eng.submit([long.fresh_copy(), short.fresh_copy()])
        saw_holder = False
        guard = 0
        while not eng.idle and guard < 10_000:
            eng.step()
            guard += 1
            queued = [e[2] for e in eng._ready]
            for r in queued:
                if r.held > 0:
                    saw_holder = True
                    # held pages are page-rounded over prompt + progress
                    assert r.held >= r.prompt_len + r.generated
                    assert r.held % spec.page_size == 0
                    assert eng.kv.reserved[r.rid] == r.held
                    # outstanding_kv counts held once (in reserved_now)
                    assert eng._ready_need == sum(
                        max(0, int(q.prompt_len + q.reserve_len) - q.held)
                        for q in queued)
        assert saw_holder
        assert len(eng.done) == 2

    def test_expire_releases_held_pages_only_on_timeout(self):
        """A preempted holder that times out while waiting releases its kept
        pages at expiry — not before — and counts as timed_out."""
        pol = _pol("keep")
        spec = ReplicaSpec(1, 1024, prefill_tokens_per_step=8, page_size=4)
        long = Request(rid=0, arrival=0.0, prompt_len=64, true_len=400,
                       predicted_len=400.0, reserve_len=420.0, deadline=60.0)
        short = Request(rid=1, arrival=20.0, prompt_len=8, true_len=100,
                        predicted_len=20.0, reserve_len=120.0)
        eng = SimEngine(policy=pol, spec=spec)
        st_row = eng.run([long, short])
        assert st_row.preemptions == 1
        assert st_row.timed_out == 1
        assert st_row.completed == 1
        assert eng.kv.reserved_now == 0 and eng._held_tokens == 0

    def test_stall_breaker_releases_held_not_deadlock(self):
        """When queued holders pin the pool and nothing is active, the
        engine must free their pages (recompute for them) instead of
        wedging the queue until max_steps."""
        pol = Policy("srtf_pred", "quantile", max_seq_len=4096, preempt=True,
                     preempt_mode="keep")
        spec = ReplicaSpec(1, 512, page_size=4)
        # big holder preempted by a short one; then a head whose need only
        # fits if the holder's pages are released
        a = Request(rid=0, arrival=0.0, prompt_len=128, true_len=300,
                    predicted_len=300.0, reserve_len=320.0)
        b = Request(rid=1, arrival=10.0, prompt_len=8, true_len=20,
                    predicted_len=20.0, reserve_len=30.0)
        c = Request(rid=2, arrival=12.0, prompt_len=64, true_len=80,
                    predicted_len=60.0, reserve_len=340.0)
        eng = SimEngine(policy=pol, spec=spec)
        st_row = eng.run([a, b, c], max_steps=100_000)
        assert st_row.preemptions == 1
        assert st_row.held_releases == 1   # a's pages freed so c could start
        assert st_row.completed == 3
        assert st_row.makespan < 10_000

    def test_grow_into_page_slack_never_emits_past_reservation(self):
        """Regression: with large pages, a request can fill its rounding
        slack so that a grow succeeds while granting few (page-rounded)
        tokens; the decode loop must re-clamp its emit so usage never
        exceeds the granted pages."""
        pol = Policy("fcfs", "quantile", max_seq_len=4096)
        for page_size, speed in ((64, 1), (4, 8)):
            spec = ReplicaSpec(2, 1024, speed=speed, page_size=page_size)
            r = Request(rid=0, arrival=0.0, prompt_len=8, true_len=150,
                        predicted_len=40.0, reserve_len=32.0)
            eng = SimEngine(policy=pol, spec=spec, vectorized=False)
            eng.submit([r.fresh_copy()])
            guard = 0
            while not eng.idle and guard < 5000:
                eng.step()
                guard += 1
                for i in range(eng._n_active):
                    assert eng._a_used[i] <= eng._a_res[i], (page_size, speed)
            assert len(eng.done) == 1
            assert 0.0 <= eng.kv.waste_ratio <= 1.0

    def test_preempt_mode_validation(self):
        with pytest.raises(ValueError):
            Policy("srtf_pred", "quantile", preempt=True, preempt_mode="oops")
        with pytest.raises(ValueError):
            ReplicaSpec(2, 100, page_size=0)
        with pytest.raises(ValueError):
            ReplicaSpec(2, 100, page_size=16)     # budget not page-aligned


class TestRequestConservation:
    """Satellite invariant: every submitted request ends in exactly one of
    done / timed_out / rejected / dropped — across preemption modes,
    stealing with in-transit expiry, admission control, and undersized
    replicas."""

    def _conserved(self, cl, reqs, st_row):
        done = [r for e in cl.engines for r in e.done]
        timed = [r for e in cl.engines for r in e.timed_out_requests]
        assert st_row["completed"] == len(done)
        assert st_row["timed_out"] == len(timed)
        assert st_row["completed"] + st_row["timed_out"] \
            + st_row["rejected"] + st_row["dropped"] == len(reqs)
        rids = sorted([r.rid for r in done] + [r.rid for r in timed]
                      + [r.rid for r in cl.rejected_requests])
        # dropped requests are counted but not retained; everything retained
        # is unique
        assert len(rids) == len(set(rids))
        for e in cl.engines:
            assert e.kv.reserved_now == 0
            assert e._held_tokens == 0

    @pytest.mark.parametrize("mode", ["recompute", "keep"])
    def test_overloaded_cluster_with_steal_and_admission(self, mode):
        reqs = _trace(n=500, rate=2.5, seed=4, slo_factor=2.0, slo_floor=30.0)
        specs = (ReplicaSpec(4, 2 * (256 + 512), speed=2, page_size=4,
                             prefill_tokens_per_step=64),
                 ReplicaSpec(2, 768, speed=1, page_size=4,
                             prefill_tokens_per_step=32))
        cl = Cluster(specs, _pol(mode), router="psq",
                     predictor=LatentOracle(), rebalance_every=20,
                     steal="quantile", steal_cost=1,
                     admission=AdmissionController(slack=0.5))
        st_row = cl.run(reqs).row()
        assert st_row["timed_out"] > 0 and st_row["rejected"] > 0
        self._conserved(cl, reqs, st_row)

    def test_in_transit_stolen_requests_expire_without_leaking(self):
        """Stolen requests delayed past their deadline (steal_cost) must
        surface from the future heap and expire as timed_out, not vanish."""
        reqs = _trace(n=400, rate=2.5, seed=8, slo_factor=2.0, slo_floor=30.0)
        specs = (ReplicaSpec(2, 256 + 512, speed=1),
                 ReplicaSpec(8, 4 * (256 + 512), speed=3))
        cl = Cluster(specs, Policy("fcfs", "quantile", quantile=0.9,
                                   max_seq_len=512),
                     router="round_robin", predictor=LatentOracle(),
                     rebalance_every=20, steal_cost=3)
        st_row = cl.run(reqs).row()
        assert st_row["stolen"] > 0 and st_row["timed_out"] > 0
        self._conserved(cl, reqs, st_row)

    def test_dropped_surfaces_in_cluster_row(self):
        """round_robin lands oversized requests on an undersized replica:
        they must appear in ClusterStats.row()['dropped'] and balance the
        conservation equation."""
        specs = (ReplicaSpec(4, 2 * (256 + 512)), ReplicaSpec(2, 500))
        reqs = _trace(n=250, rate=1.5, seed=11)
        cl = Cluster(specs, Policy("fcfs", "quantile", quantile=0.9,
                                   max_seq_len=512),
                     router="round_robin", predictor=LatentOracle())
        st_row = cl.run(reqs).row()
        assert st_row["dropped"] > 0
        self._conserved(cl, reqs, st_row)


class TestKeepPaysOff:
    def test_keep_cuts_recompute_ticks_and_latency(self):
        """Acceptance shape of the bench: at equal KV budget, in a feasible
        (non-overloaded) regime, keep-pages preemption re-pays strictly
        fewer prefill ticks than recompute, loses no completions, and the
        saved slot-time shows up as lower latency."""
        reqs = _trace(n=600, rate=0.5, seed=3)
        kv = 8 * (256 + 512) // 16 * 16
        rows = {}
        for mode in ("recompute", "keep"):
            pol = Policy("srtf_pred", "quantile", quantile=0.9,
                         max_seq_len=512, preempt=True, preempt_factor=1.2,
                         preempt_mode=mode)
            spec = ReplicaSpec(8, kv, speed=1, prefill_tokens_per_step=8,
                               page_size=16)
            eng = SimEngine(policy=pol, predictor=LatentOracle(), spec=spec)
            rows[mode] = eng.run(reqs, max_steps=1_000_000).row()
        rec, keep = rows["recompute"], rows["keep"]
        assert rec["preemptions"] > 10
        assert rec["recompute_ticks"] > 0
        assert keep["recompute_ticks"] < rec["recompute_ticks"]
        assert keep["completed"] == rec["completed"] == 600
        assert keep["mean_latency"] < rec["mean_latency"]
        assert keep["p99_latency"] <= rec["p99_latency"]
