"""RoPE/M-RoPE properties and workload-builder rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import INPUT_SHAPES, get_input_shape
from repro.models import rope


class TestRope:
    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        hd = 32
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

        def score(i, j):
            ai = rope.rope_angles(jnp.array([[i]]), hd, 10000.0)
            aj = rope.rope_angles(jnp.array([[j]]), hd, 10000.0)
            qr = rope.apply_rope(q, ai)
            kr = rope.apply_rope(k, aj)
            return float(jnp.sum(qr * kr))

        assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-5)
        assert score(7, 0) == pytest.approx(score(50, 43), rel=1e-5)

    def test_mrope_reduces_to_rope_on_text(self):
        """With t==h==w positions, M-RoPE must equal standard RoPE."""
        hd, B, S = 64, 2, 9
        pos = rope.positions_from_tokens(B, S)
        mpos = rope.text_mrope_positions(B, S)
        a1 = rope.rope_angles(pos, hd, 1e6, use_mrope=False)
        a2 = rope.rope_angles(mpos, hd, 1e6, use_mrope=True)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)

    def test_mrope_sections_sum(self):
        for hd in (64, 128, 96):
            t, h, w = rope.mrope_section(hd)
            assert t + h + w == hd // 2 and min(t, h, w) > 0

    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 2, 32))
        ang = rope.rope_angles(rope.positions_from_tokens(1, 4), 32, 1e4)
        xr = rope.apply_rope(x, ang)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x)),
                                   np.linalg.norm(np.asarray(xr)), rtol=1e-5)


class FakeMesh:
    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


class TestWorkloadRules:
    def test_cfg_for_shape_long_context(self):
        from repro.configs import get_config
        from repro.launch.workload import cfg_for_shape
        long = get_input_shape("long_500k")
        yi = cfg_for_shape(get_config("yi-34b"), long)
        assert yi.attn_window == 8192          # windowed decode variant
        gm = cfg_for_shape(get_config("gemma3-27b"), long)
        assert gm.local_global_ratio == 5      # native pattern untouched
        mb = cfg_for_shape(get_config("mamba2-130m"), long)
        assert mb.attn_window == 0             # attention-free
        tr = cfg_for_shape(get_config("yi-34b"), get_input_shape("train_4k"))
        assert tr.attn_window == 0             # train keeps full attention

    def test_input_specs_shapes(self):
        from repro.configs import get_config
        from repro.launch.workload import input_specs
        cfg = get_config("yi-34b")
        for shape in INPUT_SHAPES:
            specs, axes = input_specs(cfg, shape)
            assert set(specs) == set(axes)
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch,)
            else:
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
        # whisper gets encoder embeds; vlm gets embeds+positions(+tokens)
        wspecs, _ = input_specs(get_config("whisper-large-v3"),
                                get_input_shape("train_4k"))
        assert wspecs["enc_embeds"].shape == (256, 1500, 1280)
        vspecs, _ = input_specs(get_config("qwen2-vl-2b"),
                                get_input_shape("train_4k"))
        assert "embeds" in vspecs and "positions" in vspecs and "tokens" in vspecs

    def test_variant_registry(self):
        from repro.launch.workload import VARIANTS
        for name in ("baseline", "causal_skip", "moe_tight", "moe_partial",
                     "int8kv", "seqpar", "train_tight", "decode_opt", "nopad"):
            assert name in VARIANTS

    def test_gemma_cache_is_pattern_grouped(self):
        """Ring caches for local layers, full caches only for global layers —
        the memory design that makes 500k-context gemma fit."""
        from repro.configs import get_config
        from repro.models.model_zoo import build_model
        m = build_model(get_config("gemma3-27b"))
        specs = m.cache_specs(batch=1, cache_len=524_288)
        sizes = sorted({s.shape[2] for seg in specs
                        for e in seg.values() for s in e.values()
                        if len(s.shape) == 5})
        assert sizes == [1024, 524_288]  # local rings + global full
        # ring layers outnumber global layers 5:1
        n_ring = sum(s.shape[0] for seg in specs for e in seg.values()
                     for k, s in e.items() if k == "k" and s.shape[2] == 1024)
        n_full = sum(s.shape[0] for seg in specs for e in seg.values()
                     for k, s in e.items() if k == "k" and s.shape[2] == 524_288)
        assert n_ring == 52 and n_full == 10
