"""Serving substrate invariants + policy behavior."""

import numpy as np
import pytest

from repro.serving.engine import SimEngine
from repro.serving.kvcache import KVCacheManager
from repro.serving.request import Request
from repro.serving.scheduler import Policy, pick_next


def _mk_requests(n=40, seed=0, long_frac=0.2):
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(2.0))
        long = rng.random() < long_frac
        L = int(rng.integers(400, 900)) if long else int(rng.integers(10, 60))
        reqs.append(Request(rid=i, arrival=t, prompt_len=32, true_len=L))
    return reqs


class TestKVCache:
    def test_budget_enforced(self):
        kv = KVCacheManager(budget_tokens=100)
        assert kv.admit(0, 60)
        assert not kv.admit(1, 50)
        assert kv.admit(1, 40)
        kv.release(0)
        assert kv.admit(2, 60)

    def test_waste_accounting(self):
        kv = KVCacheManager(budget_tokens=100)
        kv.admit(0, 100)
        for _ in range(10):
            kv.use(0, 1)
            kv.tick()
        assert 0.0 < kv.waste_ratio < 1.0
        # reserved 100 for 10 steps = 1000; used integral = 1+2+..+10 = 55
        assert kv.waste_ratio == pytest.approx(1 - 55 / 1000)

    def test_grow_counts_overflow(self):
        kv = KVCacheManager(budget_tokens=100)
        kv.admit(0, 50)
        assert kv.grow(0, 20)
        assert kv.overflow_events == 1
        assert not kv.grow(0, 1000)


class TestScheduler:
    def test_fcfs_order(self):
        reqs = _mk_requests(5)
        assert pick_next(reqs, Policy("fcfs", "max"), now=1e9) == 0

    def test_sjf_oracle_picks_shortest(self):
        reqs = _mk_requests(10)
        i = pick_next(reqs, Policy("sjf_oracle", "max"), now=1e9)
        assert reqs[i].true_len == min(r.true_len for r in reqs)

    def test_no_request_from_future(self):
        reqs = [Request(rid=0, arrival=100.0, prompt_len=8, true_len=10)]
        assert pick_next(reqs, Policy("fcfs", "max"), now=1.0) is None


class TestSimEngine:
    def test_all_requests_complete(self):
        reqs = _mk_requests(30)
        eng = SimEngine(max_slots=4, kv_budget=8000,
                        policy=Policy("fcfs", "max", max_seq_len=1024))
        st = eng.run(reqs)
        assert st.completed == 30
        assert np.isfinite(st.mean_latency)

    def test_sjf_oracle_beats_fcfs_on_mean_latency(self):
        reqs = _mk_requests(60, long_frac=0.3)
        fcfs = SimEngine(2, 8000, Policy("fcfs", "oracle", max_seq_len=1024)).run(reqs)
        sjf = SimEngine(2, 8000, Policy("sjf_oracle", "oracle", max_seq_len=1024)).run(reqs)
        assert sjf.mean_latency < fcfs.mean_latency  # SJF optimality

    def test_oracle_reservation_minimizes_waste(self):
        reqs = _mk_requests(30)
        maxr = SimEngine(4, 50_000, Policy("fcfs", "max", max_seq_len=1024)).run(reqs)
        orac = SimEngine(4, 50_000, Policy("fcfs", "oracle", max_seq_len=1024)).run(reqs)
        assert orac.kv_waste_ratio < maxr.kv_waste_ratio

    def test_engine_deterministic(self):
        reqs = _mk_requests(20)
        p = Policy("fcfs", "max", max_seq_len=512)
        a = SimEngine(4, 4000, p).run(reqs)
        b = SimEngine(4, 4000, p).run(reqs)
        assert a.row() == b.row()

    def test_kv_bound_limits_concurrency(self):
        """With a tight KV budget, max-reservation admits fewer concurrent
        requests than quantile reservation would — makespan suffers."""
        reqs = _mk_requests(30, long_frac=0.0)
        tight = SimEngine(16, 2 * (32 + 1024), Policy("fcfs", "max", max_seq_len=1024)).run(reqs)
        loose = SimEngine(16, 16 * (32 + 1024), Policy("fcfs", "max", max_seq_len=1024)).run(reqs)
        assert tight.makespan > loose.makespan


class TestPreemptiveSRTF:
    def test_preemption_breaks_hol_blocking(self):
        """Long jobs occupy all slots; a burst of shorts arrives. SRTF with
        ProD-O-style remaining estimates preempts and slashes mean latency."""
        reqs = []
        for i in range(4):
            reqs.append(Request(rid=i, arrival=i * 0.1, prompt_len=16,
                                true_len=800))
        for i in range(40):
            reqs.append(Request(rid=4 + i, arrival=5.0 + i * 0.5,
                                prompt_len=16, true_len=20))
        sjf = SimEngine(4, 50_000, Policy("sjf_oracle", "oracle",
                                          max_seq_len=1024)).run(reqs)
        srtf = SimEngine(4, 50_000, Policy("srtf_pred", "oracle",
                                           max_seq_len=1024,
                                           preempt=True)).run(reqs)
        assert srtf.preemptions >= 1
        assert srtf.mean_latency < 0.5 * sjf.mean_latency
        assert srtf.completed == sjf.completed == 44

    def test_preempted_work_not_lost(self):
        """A preempted request resumes with its generated count intact."""
        reqs = [Request(rid=0, arrival=0.0, prompt_len=8, true_len=200),
                Request(rid=1, arrival=10.0, prompt_len=8, true_len=10)]
        st = SimEngine(1, 50_000, Policy("srtf_pred", "oracle",
                                         max_seq_len=512,
                                         preempt=True)).run(reqs)
        assert st.completed == 2
        # total decode steps ~ sum of lengths (progress kept on preemption)
        assert st.makespan < 200 + 10 + 30


from _hypothesis_compat import given, settings, strategies as st_

@settings(deadline=None, max_examples=25)
@given(st_.integers(2, 40), st_.integers(0, 10_000),
       st_.sampled_from(["fcfs", "sjf_oracle", "srtf_pred"]))
def test_engine_invariants_random_workloads(n, seed, order):
    """Property: every request completes exactly once, latency ≥ service
    time, waste ∈ [0,1], KV fully released at the end."""
    reqs = _mk_requests(n, seed=seed)
    pol = Policy(order, "oracle", max_seq_len=1024,
                 preempt=(order == "srtf_pred"))
    eng = SimEngine(max_slots=3, kv_budget=20_000, policy=pol)
    st = eng.run(reqs)
    assert st.completed == n
    assert 0.0 <= st.kv_waste_ratio <= 1.0
    assert eng.kv.reserved_now == 0  # everything released
    assert st.mean_latency >= np.mean([r.true_len for r in reqs]) - 1e-6
