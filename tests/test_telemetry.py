"""Serving telemetry layer (``repro.serving.telemetry``): tracer seams,
gauges, exporters, and the bench regression gate.

Pins, in order of importance:

* ``tracer=None`` bit-identity — engine and cluster rows exactly equal the
  pre-telemetry goldens (captured at the commit before the seam landed);
* trace-on path equality — the per-slot reference loop and the vectorized
  event-leap path emit the same canonical event stream and gauge series
  (including budget/chunked prefill and posterior-refine configurations);
* event-log conservation — every submitted request yields a well-ordered
  stream ending in exactly one terminal event, and terminal totals
  reconcile with the run's row;
* exporter formats — Perfetto/Chrome trace-event schema, Prometheus text
  exposition, JSON summary;
* the shared percentile helpers are the single implementation behind both
  ``ServeStats`` and ``ClusterStats``;
* ``benchmarks/check_regression.py`` passes on the committed
  ``BENCH_serving.json`` and fails on injected p99/goodput regressions;
* ``_write_stamp`` meta provenance merges non-destructively.
"""

import importlib.util
import json
import re
import sys
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.online import PosteriorRefiner
from repro.serving import adaptation as adaptation_mod
from repro.serving import engine as engine_mod
from repro.serving import telemetry
from repro.serving.adaptation import (AdaptationConfig, AdmissionController,
                                      OnlineAdapter)
from repro.serving.arrivals import LatentOracle, TraceConfig, make_trace
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.predictor import PredictorService
from repro.serving.scheduler import Policy
from repro.serving.telemetry import (EVENT_KINDS, TERMINAL_KINDS, TraceEvent,
                                     Tracer, goodput, latency_summary,
                                     percentile_summary, ttft_summary)

REPO = Path(__file__).resolve().parents[1]


def _load_bench(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "benchmarks" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# the golden serving configurations (captured at the pre-telemetry commit)
# ---------------------------------------------------------------------------

CFG_E = TraceConfig(n_requests=220, pattern="bursty", rate=1.4, seed=11,
                    model="llama", scenario="math", max_seq_len=512,
                    slo_factor=4.0, slo_floor=100.0)
POL = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
             preempt=True, preempt_factor=1.5, preempt_mode="keep")
SPEC_E = ReplicaSpec(max_slots=6, kv_budget=3072, speed=2, page_size=16,
                     step_token_budget=96, prefill_chunk_tokens=48)

CFG_C = TraceConfig(n_requests=300, pattern="bursty", rate=1.6, seed=23,
                    model="llama", scenario="math", max_seq_len=512,
                    slo_factor=3.0, slo_floor=80.0, session_frac=0.2,
                    system_prompt_len=64)
SPECS_C = (ReplicaSpec(6, 3072, speed=2, prefill_tokens_per_step=64,
                       page_size=16, share_prefixes=True),
           ReplicaSpec(4, 2048, speed=1, prefill_tokens_per_step=32,
                       page_size=8, share_prefixes=True))

ENGINE_GOLDEN = {
    'completed': 95, 'cow_copies': 0, 'dropped': 0,
    'frag_ratio': 0.04205969033357382, 'goodput': 8.102222222222222,
    'held_peak': 528, 'held_releases': 0, 'held_steps': 416720.0,
    'kv_amplification': 1.0, 'kv_waste_ratio': 0.4400298408199814,
    'makespan': 900.0, 'mean_latency': 306.3208129370655,
    'mean_ttft': 257.16291820022343, 'mean_wait': 252.1734445160129,
    'occupancy': 0.6656655092592593, 'oom_evictions': 0,
    'overflow_events': 15, 'p50_latency': 298.2692171933342,
    'p50_ttft': 233.49590602556097, 'p90_latency': 644.7828585096365,
    'p90_ttft': 587.6682439608038, 'p99_latency': 704.013124592086,
    'p99_ttft': 622.7348519964685, 'page_size': 16, 'peak_reserved': 2640,
    'policy': 'srtf_pred+quantile', 'preemptions': 4,
    'prefill_saved_ticks': 0, 'prefill_ticks': 364, 'prefix_evictions': 0,
    'prefix_hits': 0, 'recompute_ticks': 0, 'refine_events': 0,
    'refine_grows': 0, 'refine_shrinks': 0, 'shared_peak': 0,
    'slo_violations': 15, 'throughput': 10.323333333333334, 'timed_out': 125,
}

CLUSTER_GOLDEN = {
    'balance': 1.4838637881148453, 'completed': 86, 'cow_copies': 0,
    'dropped': 0, 'frag_ratio': -0.19515624100568107,
    'goodput': 11.767857142857142, 'held_peak': 776,
    'held_releases': 0, 'held_steps': 210896.0,
    'kv_amplification': 1.2173248847620186,
    'kv_waste_ratio': 0.3301723145454465, 'makespan': 672.0,
    'mean_latency': 230.34985295918955, 'mean_ttft': 159.52427156384067,
    'mean_wait': 154.9545041219802, 'n_replicas': 2,
    'occupancy': 0.5251046316964286, 'oom_evictions': 0,
    'overflow_events': 11, 'p50_latency': 184.68996795046922,
    'p50_ttft': 72.39838470510918, 'p90_latency': 463.7134716716017,
    'p90_ttft': 387.8992511807843, 'p99_latency': 548.7989852052087,
    'p99_ttft': 468.5890644095952, 'policy': 'srtf_pred+quantile',
    'preemptions': 7, 'prefill_saved_ticks': 121, 'prefill_ticks': 311,
    'prefix_evictions': 0, 'prefix_hits': 91, 'recompute_ticks': 0,
    'refine_events': 0,
    'refine_grows': 0, 'refine_shrinks': 0, 'refreshes': 0, 'rejected': 197,
    'router': 'psq', 'shared_peak': 128, 'slo_violations': 8,
    'steal_delay': 0, 'steal_pages': 312, 'stolen': 15,
    'throughput': 13.37202380952381, 'timed_out': 17,
}


def _run_engine(vectorized, tracer=None):
    eng = SimEngine(spec=SPEC_E, policy=POL, predictor=LatentOracle(),
                    vectorized=vectorized, tracer=tracer)
    return eng.run(make_trace(CFG_E)).row()


def _run_cluster(vectorized, tracer=None):
    cl = Cluster(list(SPECS_C), POL, router="psq", predictor=LatentOracle(),
                 rebalance_every=40, steal="quantile", steal_cost=0.05,
                 admission=AdmissionController(slack=0.8, tracer=tracer),
                 vectorized=vectorized, tracer=tracer)
    return cl.run(make_trace(CFG_C)).row()


@pytest.fixture(scope="module")
def engine_traced():
    """(row, tracer) per decode path, same golden engine config."""
    out = {}
    for vec in (True, False):
        tr = Tracer(sample_every=64)
        out[vec] = (_run_engine(vec, tracer=tr), tr)
    return out


@pytest.fixture(scope="module")
def cluster_traced():
    """(row, tracer) per decode path, same golden cluster config —
    exercises routing, admission, prefix sharing, stealing, preemption."""
    out = {}
    for vec in (True, False):
        tr = Tracer(sample_every=64)
        out[vec] = (_run_cluster(vec, tracer=tr), tr)
    return out


# ---------------------------------------------------------------------------
# tracer=None bit-identity (golden-pinned) + trace-on non-perturbation
# ---------------------------------------------------------------------------


class TestTracerOffGoldens:
    def test_engine_row_bit_identical(self, engine_traced):
        assert _run_engine(True, tracer=None) == ENGINE_GOLDEN
        # tracing observes without perturbing: traced rows hit the same
        # golden bit-for-bit
        assert engine_traced[True][0] == ENGINE_GOLDEN
        assert engine_traced[False][0] == ENGINE_GOLDEN

    def test_cluster_row_bit_identical(self, cluster_traced):
        assert _run_cluster(True, tracer=None) == CLUSTER_GOLDEN
        assert cluster_traced[True][0] == CLUSTER_GOLDEN
        assert cluster_traced[False][0] == CLUSTER_GOLDEN


# ---------------------------------------------------------------------------
# trace-on: reference vs vectorized event-leap bit-exactness
# ---------------------------------------------------------------------------


class TestPathEquality:
    def test_engine_streams_bitexact(self, engine_traced):
        tv, tf = engine_traced[True][1], engine_traced[False][1]
        assert tv.emitted > 0
        assert tv.canonical() == tf.canonical()
        assert tv.series == tf.series
        assert tv.counts == tf.counts

    def test_cluster_streams_bitexact(self, cluster_traced):
        tv, tf = cluster_traced[True][1], cluster_traced[False][1]
        assert tv.canonical() == tf.canonical()
        assert tv.series == tf.series
        # the golden cluster exercises the interesting kinds
        for kind in ("arrival", "routed", "admission", "rejected", "admitted",
                     "first_token", "preempted", "stolen", "finish",
                     "timeout"):
            assert tv.counts[kind] > 0, kind

    def test_refine_streams_bitexact(self, shared_head):
        """Posterior refinement (evented refine ticks) + a real
        PredictorService (predict-window events) stay path-identical."""
        cfg = TraceConfig(n_requests=120, pattern="poisson", rate=1.2,
                          seed=5, model="llama", scenario="math",
                          max_seq_len=512, slo_factor=6.0, slo_floor=200.0)
        pol = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
                     preempt=True, preempt_factor=1.5, preempt_mode="keep",
                     refine_every=16)
        spec = ReplicaSpec(max_slots=8, kv_budget=4096, speed=2,
                           prefill_tokens_per_step=64, page_size=16)
        edges = np.asarray(shared_head.edges, np.float64)
        tracers = {}
        for vec in (True, False):
            tr = Tracer(sample_every=48)
            svc = PredictorService(shared_head, window=8.0, tracer=tr)
            eng = SimEngine(spec=spec, policy=pol, predictor=svc,
                            vectorized=vec, tracer=tr,
                            refiner=PosteriorRefiner(edges))
            eng.run(make_trace(cfg))
            tracers[vec] = tr
        tv, tf = tracers[True], tracers[False]
        assert tv.canonical() == tf.canonical()
        assert tv.series == tf.series
        assert tv.counts["refine"] > 0
        assert tv.counts["predict"] > 0


# ---------------------------------------------------------------------------
# event-log conservation invariant
# ---------------------------------------------------------------------------

_LIFECYCLE = ("arrival", "routed", "admitted", "first_token")


def _check_conservation(tracer, row, n_submitted, has_dispatch):
    term = tracer.terminal_counts()
    assert term["finish"] == row["completed"]
    assert term["timeout"] == row["timed_out"]
    assert term["dropped"] == row["dropped"]
    assert term["rejected"] == row.get("rejected", 0)
    assert sum(term.values()) == n_submitted
    streams = tracer.by_rid()
    assert tracer.counts["arrival"] == n_submitted
    for rid, evs in streams.items():
        kinds = [e.kind for e in evs]
        assert kinds[0] == "arrival", (rid, kinds)
        terminal = [k for k in kinds if k in TERMINAL_KINDS]
        assert len(terminal) == 1, (rid, kinds)
        assert kinds[-1] in TERMINAL_KINDS, (rid, kinds)
        # well-ordered: arrival <= routed <= admitted <= first_token <= end
        first_t = {}
        for e in evs:
            first_t.setdefault(e.kind, e.t)
        seen = [first_t[k] for k in _LIFECYCLE if k in first_t]
        assert seen == sorted(seen), (rid, first_t)
        assert evs[-1].t >= seen[-1]
        if has_dispatch and kinds[-1] != "rejected":
            # every dispatched request was routed; a queued one may time
            # out without ever reaching a slot, but a finisher was admitted
            assert "routed" in first_t, (rid, kinds)
        if kinds[-1] == "finish" or "first_token" in first_t:
            assert "admitted" in first_t, (rid, kinds)
        if "first_token" in first_t:
            assert kinds.count("first_token") == 1


class TestConservation:
    def test_engine_log_conserves_requests(self, engine_traced):
        row, tr = engine_traced[True]
        _check_conservation(tr, row, CFG_E.n_requests, has_dispatch=False)

    def test_cluster_log_conserves_requests(self, cluster_traced):
        row, tr = cluster_traced[True]
        _check_conservation(tr, row, CFG_C.n_requests, has_dispatch=True)


# ---------------------------------------------------------------------------
# tracer mechanics: ring buffer, canonical order, residual histograms
# ---------------------------------------------------------------------------


class TestTracerMechanics:
    def test_knob_validation(self):
        with pytest.raises(ValueError, match="capacity"):
            Tracer(capacity=0)
        with pytest.raises(ValueError, match="sample_every"):
            Tracer(sample_every=-1)

    def test_ring_buffer_bounds_memory_not_counts(self):
        tr = Tracer(capacity=4)
        for i in range(7):
            tr.emit(float(i), 0, i, "arrival")
        assert len(tr.events) == 4
        assert tr.emitted == 7
        assert tr.counts["arrival"] == 7
        assert [e.rid for e in tr.events] == [3, 4, 5, 6]
        assert tr.summary()["evicted"] == 3

    def test_canonical_orders_lifecycle_within_tick(self):
        tr = Tracer()
        tr.emit(5.0, 0, 1, "finish", gen=3)
        tr.emit(5.0, 0, 1, "first_token")
        tr.emit(2.0, 0, 1, "arrival")
        kinds = [e.kind for e in tr.canonical()]
        assert kinds == ["arrival", "first_token", "finish"]

    def test_residual_histograms_per_class(self):
        tr = Tracer(residual_window=8)
        for true, pred, cls in ((100, 90, "math"), (400, 90, "math"),
                                (50, 60, "chat")):
            tr.observe_residual(SimpleNamespace(
                predicted_len=float(pred), true_len=float(true), setting=cls,
                cal_q=120.0, reserve_len=None))
        tr._snapshot_residuals(10.0)
        by_cls = {r["class"]: r for r in tr.residual_series}
        assert set(by_cls) == {"math", "chat"}
        m = by_cls["math"]
        assert m["n"] == 2
        assert sum(m["counts"]) == 2
        assert m["mean_residual"] == pytest.approx((10 + 310) / 2)
        assert m["coverage"] == pytest.approx(0.5)   # 400 > cal_q 120
        # unannotated requests carry no residual sample
        tr.observe_residual(SimpleNamespace(predicted_len=None))
        assert sum(len(w) for w in tr._res.values()) == 3


# ---------------------------------------------------------------------------
# seam units: admission + adapter refresh events
# ---------------------------------------------------------------------------


class TestSeamUnits:
    def test_admission_controller_emits_decisions(self):
        tr = Tracer()
        ac = AdmissionController(slack=1.0, tracer=tr)
        spec = ReplicaSpec(4, 2048, speed=2, prefill_tokens_per_step=32)
        eng = SimpleNamespace(replica_id=3, predicted_backlog=lambda: 0.0)
        ok = ac.admit(SimpleNamespace(rid=7, deadline=1e6, reserve_len=64.0,
                                      prompt_len=32), eng, spec, now=10.0)
        bad = ac.admit(SimpleNamespace(rid=8, deadline=11.0, reserve_len=512.0,
                                       prompt_len=512), eng, spec, now=10.0)
        free = ac.admit(SimpleNamespace(rid=9, deadline=None), eng, spec, 10.0)
        assert (ok, bad, free) == (True, False, True)
        evs = tr.canonical()
        assert [e.kind for e in evs] == ["admission"] * 3
        by = {e.rid: dict(e.data) for e in evs}
        assert by[7]["ok"] == 1 and by[8]["ok"] == 0 and by[9]["ok"] == 1
        assert by[8]["eta"] > by[8]["deadline"]
        assert all(e.replica == 3 for e in evs)
        # the tracer field stays out of the frozen dataclass's identity
        assert AdmissionController(slack=1.0) == ac

    def test_adapter_refresh_emits_version(self, monkeypatch):
        tr = Tracer()
        base = SimpleNamespace(predictor="w0",
                               swap_weights=lambda w: None)
        cfg = AdaptationConfig(refresh_every=4, refresh_min_samples=2)
        ad = OnlineAdapter(base, cfg, tracer=tr)
        monkeypatch.setattr(adaptation_mod, "refit_head",
                            lambda *a, **k: "w1")
        ad._buf_phi.extend([np.zeros(3), np.zeros(3)])
        ad._buf_len.extend([10.0, 20.0])
        assert ad.maybe_refresh(now=8.0)
        assert tr.counts["refresh"] == 1
        (ev,) = [e for e in tr.canonical() if e.kind == "refresh"]
        assert dict(ev.data) == {"version": 1, "alarmed": 0, "buffer": 2}


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


class TestExporters:
    def test_perfetto_schema(self, cluster_traced, tmp_path):
        tr = cluster_traced[True][1]
        path = tmp_path / "trace.json"
        tr.write_perfetto(str(path))
        doc = json.loads(path.read_text())   # valid JSON round-trip
        evs = doc["traceEvents"]
        assert isinstance(evs, list) and evs
        assert doc["displayTimeUnit"] == "ms"
        names = set()
        for e in evs:
            assert e["ph"] in ("X", "M", "i", "C"), e
            assert isinstance(e["pid"], int) and e["pid"] >= 0
            if e["ph"] == "X":
                assert isinstance(e["ts"], float) and e["ts"] >= 0.0
                assert isinstance(e["dur"], float) and e["dur"] > 0.0
                assert isinstance(e["tid"], int) and e["tid"] >= 1
                names.add(e["name"].split(" ")[0])
            elif e["ph"] == "M":
                assert e["name"] in ("process_name", "thread_name")
                assert "name" in e["args"]
            elif e["ph"] == "i":
                assert e["s"] == "t" and "rid" in e["args"]
            else:
                assert len(e["args"]) == 1
        assert {"prefill", "decode"} <= names
        # replica lanes: every X span sits inside a named process/thread
        procs = {e["pid"] for e in evs if e["ph"] == "M"
                 and e["name"] == "process_name"}
        assert {e["pid"] for e in evs if e["ph"] == "X"} <= procs
        # instants cover the preempt/steal lifecycle the run exercised
        inames = {e["name"] for e in evs if e["ph"] == "i"}
        assert {"preempt", "steal", "timeout", "reject"} <= inames
        # no overlapping spans within one lane (greedy packing is valid)
        lanes = {}
        for e in evs:
            if e["ph"] == "X":
                lanes.setdefault((e["pid"], e["tid"]), []).append(
                    (e["ts"], e["ts"] + e["dur"]))
        for spans in lanes.values():
            spans.sort()
            for (_, end0), (start1, _) in zip(spans, spans[1:]):
                assert start1 >= end0

    def test_prometheus_format(self, cluster_traced):
        text = cluster_traced[True][1].to_prometheus()
        assert text.endswith("\n")
        metric = re.compile(
            r'^serving_[a-z0-9_]+\{[a-z_]+="[^"]*"\} -?[0-9eE.+naif-]+$')
        for line in text.splitlines():
            assert line.startswith("#") or metric.match(line), line
        assert "# TYPE serving_events_total counter" in text
        assert 'serving_events_total{kind="arrival"} 300' in text
        assert "# TYPE serving_kv_occupancy gauge" in text
        assert "serving_residual_coverage" in text

    def test_summary_roundtrip(self, cluster_traced, tmp_path):
        tr = cluster_traced[True][1]
        path = tmp_path / "summary.json"
        tr.write_summary(str(path))
        doc = json.loads(path.read_text())
        assert doc["emitted"] == tr.emitted
        assert doc["terminal"] == tr.terminal_counts()
        assert doc["counts"]["finish"] == CLUSTER_GOLDEN["completed"]
        assert len(doc["series"]) == len(tr.series)
        assert doc["residuals"] and doc["residual_edges"]
        # gauge rows carry the advertised keys
        fleet = [r for r in doc["series"] if r["replica"] == -1]
        per = [r for r in doc["series"] if r["replica"] >= 0]
        assert fleet and per
        # (the golden cluster routes via a stat-less LatentOracle, so no
        # predictor_hit_rate column here — run_obs covers the service path)
        assert {"kv_occupancy", "kv_frag", "queue_depth", "stolen",
                "rejected", "active_slots"} <= set(fleet[0])
        assert {"kv_occupancy", "kv_frag", "kv_amplification", "queue_depth",
                "slot_util", "held_tokens"} <= set(per[0])


# ---------------------------------------------------------------------------
# shared percentile summarization (the engine/cluster dedup)
# ---------------------------------------------------------------------------


class TestSharedSummaries:
    def test_single_implementation(self):
        assert engine_mod._latency_stats is telemetry.latency_summary
        assert engine_mod._ttft_stats is telemetry.ttft_summary
        assert engine_mod._goodput is telemetry.goodput

    def test_matches_hand_computed_quantiles(self):
        rng = np.random.default_rng(3)
        vals = rng.exponential(100.0, size=257)
        out = percentile_summary(vals, "latency")
        assert out["mean_latency"] == float(vals.mean())
        for q, name in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
            assert out[f"{name}_latency"] == float(np.quantile(vals, q))

    def test_empty_is_inf_not_zero(self):
        out = percentile_summary([], "ttft")
        assert all(v == float("inf") for v in out.values())
        assert latency_summary([])["mean_wait"] == float("inf")

    def test_object_views(self):
        done = [SimpleNamespace(latency=10.0, wait=2.0, true_len=5,
                                slo_met=True, t_first_token=4.0, arrival=1.0),
                SimpleNamespace(latency=30.0, wait=6.0, true_len=7,
                                slo_met=False, t_first_token=None,
                                arrival=2.0)]
        lat = latency_summary(done)
        assert lat["mean_latency"] == 20.0 and lat["mean_wait"] == 4.0
        ttft = ttft_summary(done)
        assert ttft["mean_ttft"] == 3.0       # only the first has a token
        assert goodput(done, makespan=5.0) == 1.0   # 5 in-SLO tokens / 5


# ---------------------------------------------------------------------------
# bench regression gate + stamp provenance
# ---------------------------------------------------------------------------


def _mini_stamp(p99=100.0, p99_ttft=50.0, gp=40.0, router="psq", meta=None):
    return {"meta": dict(meta or {"n_requests": 10, "seed": 0}),
            "tables": {"cluster": {"rows": [{
                "router": router, "policy": "fcfs+quantile",
                "p99_latency": p99, "p99_ttft": p99_ttft, "goodput": gp,
            }], "checks": {}}}}


class TestCheckRegression:
    @pytest.fixture(scope="class")
    def cr(self):
        return _load_bench("check_regression")

    def test_committed_stamp_passes_against_itself(self, cr):
        doc = cr.load_stamp(str(REPO / "BENCH_serving.json"))
        violations, skipped, compared = cr.compare(doc, doc, 0.10, 0.95)
        assert violations == [] and skipped == []
        assert len(compared) > 0

    def test_fails_on_injected_p99_regression(self, cr):
        v, _, _ = cr.compare(_mini_stamp(), _mini_stamp(p99=150.0), 0.10, 0.95)
        assert len(v) == 1 and "p99_latency" in v[0]
        v, _, _ = cr.compare(_mini_stamp(), _mini_stamp(p99_ttft=80.0),
                             0.10, 0.95)
        assert len(v) == 1 and "p99_ttft" in v[0]
        # within tolerance passes
        v, _, _ = cr.compare(_mini_stamp(), _mini_stamp(p99=105.0), 0.10, 0.95)
        assert v == []

    def test_fails_on_goodput_drop(self, cr):
        v, _, _ = cr.compare(_mini_stamp(), _mini_stamp(gp=20.0), 0.10, 0.95)
        assert len(v) == 1 and "goodput" in v[0]

    def test_meta_mismatch_is_a_failure_unless_ignored(self, cr):
        other = _mini_stamp(meta={"n_requests": 99, "seed": 0})
        v, _, compared = cr.compare(_mini_stamp(), other, 0.10, 0.95)
        assert len(v) == 1 and "meta mismatch" in v[0] and compared == []
        v, _, compared = cr.compare(_mini_stamp(), other, 0.10, 0.95,
                                    ignore_meta=True)
        assert v == [] and compared

    def test_matrix_change_skips_not_fails(self, cr):
        v, skipped, _ = cr.compare(_mini_stamp(),
                                   _mini_stamp(router="jsq", p99=500.0),
                                   0.10, 0.95)
        assert v == [] and len(skipped) == 1

    def test_cli_exit_codes(self, cr, tmp_path):
        base, cand = tmp_path / "b.json", tmp_path / "c.json"
        base.write_text(json.dumps(_mini_stamp()))
        cand.write_text(json.dumps(_mini_stamp(p99=500.0)))
        assert cr.main(["--baseline", str(base), "--candidate",
                        str(base)]) == 0
        assert cr.main(["--baseline", str(base), "--candidate",
                        str(cand)]) == 1


class TestStampProvenance:
    def test_meta_merges_non_destructively(self, tmp_path):
        bs = _load_bench("bench_serving")
        path = str(tmp_path / "stamp.json")
        bs._write_stamp(path, {"a": {"rows": [], "checks": {}}},
                        timestamp="2026-08-08T00:00:00Z", n_requests=5)
        # a later partial refresh: new table, no timestamp supplied
        bs._write_stamp(path, {"b": {"rows": [{"x": np.float64(1.5)}],
                                     "checks": {"ok": np.bool_(True)}}},
                        n_requests=5, seed=3)
        doc = json.loads(Path(path).read_text())
        assert set(doc["tables"]) == {"a", "b"}
        assert doc["meta"]["timestamp"] == "2026-08-08T00:00:00Z"
        assert doc["meta"]["n_requests"] == 5 and doc["meta"]["seed"] == 3
        assert isinstance(doc["meta"]["git_sha"], str)
        # numpy scalars were scrubbed to JSON natives
        assert doc["tables"]["b"]["rows"][0]["x"] == 1.5
        assert doc["tables"]["b"]["checks"]["ok"] is True

    def test_committed_stamp_has_provenance(self):
        doc = json.loads((REPO / "BENCH_serving.json").read_text())
        assert "git_sha" in doc["meta"] and "timestamp" in doc["meta"]
