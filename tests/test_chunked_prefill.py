"""Chunked prefill under a per-step token budget, plus the PR's bugfix
regressions: sharing-aware head servability, page-rounded steal fit, and the
``reserve="max"`` quantile fallback.

Covers the tentpole acceptance criteria directly:

* vec-vs-ref bit-exactness of budgeted runs over a random sweep of budgets ×
  chunk sizes × chunk orders × speeds × policies (property test);
* ``step_token_budget=None`` bit-identity with pre-chunking golden rows
  (engine + cluster), so the legacy paths provably did not move;
* TTFT monotonicity — chunked prefill never worsens mean TTFT vs atomic
  prefill at the same budget on a feasible trace;
* chunk-aware admission ETA and predictor batch capping.
"""

import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.serving.adaptation import AdmissionController
from repro.serving.arrivals import LatentOracle, TraceConfig, make_trace
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.predictor import PredictorService
from repro.serving.request import Request
from repro.serving.scheduler import Policy, quantile_remaining, order_key

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def _trace(n=120, rate=1.0, seed=5, **kw):
    kw.setdefault("max_seq_len", 512)
    kw.setdefault("model", "llama")
    kw.setdefault("scenario", "math")
    kw.setdefault("slo_factor", 6.0)
    kw.setdefault("slo_floor", 200.0)
    return make_trace(TraceConfig(n_requests=n, rate=rate, seed=seed, **kw))


def _run(spec, pol, reqs, vectorized=True):
    eng = SimEngine(spec=spec, policy=pol, predictor=LatentOracle(),
                    vectorized=vectorized)
    return eng.run(reqs).row()


TRACE = _trace()


class TestKnobValidation:
    def test_budget_and_pts_mutually_exclusive(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            ReplicaSpec(4, 1024, prefill_tokens_per_step=32,
                        step_token_budget=64)

    def test_chunk_needs_budget(self):
        with pytest.raises(ValueError, match="budget"):
            ReplicaSpec(4, 1024, prefill_chunk_tokens=16)

    def test_budget_positive(self):
        with pytest.raises(ValueError):
            ReplicaSpec(4, 1024, step_token_budget=0)

    def test_chunk_order_validated(self):
        with pytest.raises(ValueError, match="chunk_order"):
            Policy("fcfs", chunk_order="lifo")
        assert Policy("fcfs", chunk_order="prod").chunk_order == "prod"


class TestGoldenBitIdentity:
    """``step_token_budget=None`` must leave every legacy number untouched.

    The expected values are the exact rows this configuration produced
    BEFORE the chunked-prefill code existed (captured at the pre-change
    commit). Equality is exact — no tolerance."""

    ENGINE_GOLDEN = dict(
        makespan=1324.0, mean_latency=475.0483021597908,
        p50_latency=394.0754251350395, p90_latency=977.3657043236717,
        p99_latency=1074.8043175058644, mean_wait=412.9751314280835,
        throughput=14.694864048338369, kv_waste_ratio=0.3374401211442367,
        overflow_events=15, peak_reserved=3056, completed=164, timed_out=86,
        slo_violations=16, goodput=12.586858006042297, page_size=16,
        occupancy=0.5426058581948641, frag_ratio=0.023384698199692244,
        prefill_ticks=404,
    )
    CLUSTER_GOLDEN = dict(
        makespan=1387.0, mean_latency=486.55343787097394, completed=191,
        timed_out=59, stolen=22, steal_pages=407,
        balance=1.6092216203005987, prefill_ticks=561,
    )

    POL = Policy("sjf_pred", "quantile", quantile=0.9, max_seq_len=512)
    SPEC = ReplicaSpec(max_slots=8, kv_budget=4096, speed=2,
                       prefill_tokens_per_step=64, page_size=16)

    def _golden_trace(self):
        return _trace(n=250, rate=1.2, seed=3)

    def test_engine_row_unchanged(self):
        row = _run(self.SPEC, self.POL, self._golden_trace())
        for k, v in self.ENGINE_GOLDEN.items():
            assert row[k] == v, (k, row[k], v)

    def test_cluster_row_unchanged(self):
        specs = (self.SPEC,
                 ReplicaSpec(4, 2048, speed=1, prefill_tokens_per_step=32,
                             page_size=8))
        cl = Cluster(specs, self.POL, router="psq", predictor=LatentOracle(),
                     rebalance_every=64, steal="quantile")
        row = cl.run(self._golden_trace()).row()
        for k, v in self.CLUSTER_GOLDEN.items():
            assert row[k] == v, (k, row[k], v)


class TestBudgetedVecRefExactness:
    """The budgeted tick must be bit-exact between the vectorized path (which
    drops to the reference budget tick on constrained ticks and leaps
    unconstrained spans) and the pure per-slot reference loop."""

    @given(st.integers(48, 256),            # step token budget
           st.sampled_from([0, 16, 32, 64]),  # chunk (0 = atomic)
           st.sampled_from(["fcfs", "prod"]),
           st.sampled_from([1, 2, 4]),      # speed
           st.sampled_from(["fcfs", "sjf_pred", "edf", "laxity"]))
    def test_vec_matches_ref(self, budget, chunk, corder, speed, order):
        pol = Policy(order, "quantile", quantile=0.9, max_seq_len=512,
                     chunk_order=corder)
        spec = ReplicaSpec(max_slots=8, kv_budget=4096, speed=speed,
                           step_token_budget=budget,
                           prefill_chunk_tokens=chunk, page_size=16)
        a = _run(spec, pol, TRACE, vectorized=True)
        b = _run(spec, pol, TRACE, vectorized=False)
        assert a == b

    def test_vec_matches_ref_with_sharing(self):
        reqs = _trace(n=100, seed=9, session_frac=0.6, system_prompt_len=64)
        pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512,
                     chunk_order="prod")
        spec = ReplicaSpec(max_slots=8, kv_budget=4096, speed=2,
                           step_token_budget=96, prefill_chunk_tokens=32,
                           page_size=16, share_prefixes=True)
        assert _run(spec, pol, reqs, True) == _run(spec, pol, reqs, False)


class TestTTFT:
    def test_ttft_monotone_chunked_vs_atomic(self):
        """At the same step budget, chunked prefill (decode keeps flowing
        while prompts stream in) must not worsen mean TTFT vs atomic
        prefill (whole budget stalls on each prompt)."""
        pol = Policy("sjf_pred", "quantile", quantile=0.9, max_seq_len=512)
        rows = {}
        for chunk in (0, 32, 64):
            spec = ReplicaSpec(max_slots=8, kv_budget=4096, speed=2,
                               step_token_budget=128,
                               prefill_chunk_tokens=chunk, page_size=16)
            rows[chunk] = _run(spec, pol, TRACE)
        assert rows[32]["mean_ttft"] <= rows[0]["mean_ttft"]
        assert rows[64]["mean_ttft"] <= rows[0]["mean_ttft"]

    def test_ttft_fields_populated(self):
        pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)
        spec = ReplicaSpec(max_slots=8, kv_budget=4096, speed=2,
                           step_token_budget=128, prefill_chunk_tokens=32,
                           page_size=16)
        row = _run(spec, pol, TRACE)
        assert np.isfinite(row["mean_ttft"])
        assert row["mean_ttft"] <= row["p50_ttft"] * 10  # sane scale
        assert row["p50_ttft"] <= row["p90_ttft"] <= row["p99_ttft"]
        # TTFT can never exceed full latency on the same population
        assert row["mean_ttft"] <= row["mean_latency"]

    def test_ttft_in_legacy_mode_and_cluster(self):
        """TTFT is recorded on the legacy (non-budget) paths too — tick,
        vectorized, and leap — and aggregated by the cluster."""
        pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)
        spec = ReplicaSpec(max_slots=8, kv_budget=4096, speed=2,
                           prefill_tokens_per_step=64, page_size=16)
        row = _run(spec, pol, TRACE)
        assert np.isfinite(row["mean_ttft"])
        cl = Cluster((spec, spec), pol, router="jsq",
                     predictor=LatentOracle())
        crow = cl.run(TRACE).row()
        assert np.isfinite(crow["p99_ttft"])

    def test_request_ttft_property(self):
        r = Request(rid=0, arrival=10.0, prompt_len=4, true_len=8)
        assert r.ttft == np.inf
        r.t_first_token = 25.0
        assert r.ttft == 15.0
        assert r.fresh_copy().t_first_token is None


class TestServableHeadRegression:
    """Bugfix: the unservable-head drop must route through the KV pool's
    sharing-aware feasibility, not a raw ``pages_for(need) > pages_total``
    test. A session follow-up whose resident shared prefix covers part of
    its need used to be dropped as unservable even though the pool itself
    said it could start."""

    def test_shared_prefix_head_not_dropped(self):
        spec = ReplicaSpec(max_slots=2, kv_budget=256, page_size=16,
                           share_prefixes=True)
        pol = Policy("fcfs", "oracle", max_seq_len=512)
        # A seeds the shared prefix (8 pages resident after it finishes);
        # B's raw need (272 tokens = 17 pages) exceeds the 16-page pool, but
        # 8 of those pages are the already-resident prefix.
        a = Request(rid=0, arrival=0.0, prompt_len=128, true_len=16,
                    prefix_id="s", prefix_len=128)
        b = Request(rid=1, arrival=4.0, prompt_len=160, true_len=112,
                    prefix_id="s", prefix_len=128, deadline=400.0)
        eng = SimEngine(spec=spec, policy=pol, vectorized=True)
        st_ = eng.run([a, b])
        assert st_.dropped == 0          # pre-fix: b dropped on first check
        assert st_.completed + st_.timed_out == 2

    def test_oversized_raw_need_still_dropped(self):
        """No sharing in play: a request larger than the whole pool is still
        recognized as unservable and dropped."""
        spec = ReplicaSpec(max_slots=2, kv_budget=256, page_size=16)
        pol = Policy("fcfs", "oracle", max_seq_len=512)
        big = Request(rid=0, arrival=0.0, prompt_len=200, true_len=112)
        st_ = SimEngine(spec=spec, policy=pol).run([big])
        assert st_.dropped == 1


class TestStealFitRounding:
    """Bugfix: ``steal_queued(fit=)`` must compare the THIEF's page-rounded
    grant, not raw tokens — a raw comparison passes requests whose rounded
    need exceeds the thief's pool, which then drops them on arrival."""

    def _engine_with_queue(self, needs):
        pol = Policy("fcfs", "oracle", max_seq_len=512)
        eng = SimEngine(spec=ReplicaSpec(4, 1024, page_size=16), policy=pol)
        eng.reset()
        for i, (prompt, res) in enumerate(needs):
            r = Request(rid=i, arrival=0.0, prompt_len=prompt, true_len=res,
                        reserve_len=float(res))
            eng._push_ready(r)
        return eng

    def test_rounded_need_filter(self):
        # raw needs 30 and 14; at thief page size 16 they round to 32 and 16
        eng = self._engine_with_queue([(20, 10), (8, 6)])
        out = eng.steal_queued(2, fit=31, fit_page_size=16)
        assert [int(r.prompt_len + r.reserve_len) for r in out] == [14]

    def test_page_size_one_reproduces_raw_filter(self):
        eng = self._engine_with_queue([(20, 10), (8, 6)])
        out = eng.steal_queued(2, fit=31, fit_page_size=1)
        assert sorted(int(r.prompt_len + r.reserve_len) for r in out) \
            == [14, 30]

    def test_cluster_passes_thief_page_size(self):
        """The cluster steal path must forward the thief's page size."""
        specs = (ReplicaSpec(8, 8 * (256 + 512), speed=1, page_size=4),
                 ReplicaSpec(2, 512, speed=4, page_size=8))
        reqs = _trace(n=300, seed=6, pattern="bursty", rate=1.5)
        seen = []
        orig = SimEngine.steal_queued

        def spy(self, k, mode="tail", fit=None, fit_page_size=1):
            seen.append((fit, fit_page_size))
            return orig(self, k, mode, fit, fit_page_size)

        SimEngine.steal_queued = spy
        try:
            Cluster(specs, Policy("fcfs", "quantile", quantile=0.9,
                                  max_seq_len=512),
                    router="psq", predictor=LatentOracle(),
                    rebalance_every=20, steal="quantile").run(reqs)
        finally:
            SimEngine.steal_queued = orig
        assert seen
        legal = {(s.kv_budget, s.page_size) for s in specs}
        assert set(seen) <= legal


class TestQuantileFallbackRegression:
    """Bugfix: under ``reserve="max"`` every request's ``reserve_len`` is the
    policy cap — an uninformative constant that used to masquerade as a
    per-request quantile in laxity ordering and quantile stealing. With the
    cap passed, the fallback skips it and uses the point prediction."""

    def _req(self, reserve, **kw):
        return Request(rid=0, arrival=0.0, prompt_len=10, true_len=100,
                       reserve_len=reserve, **kw)

    def test_cap_reservation_falls_through_to_point_prediction(self):
        r = self._req(512.0, predicted_len=50.0, generated=10)
        assert quantile_remaining(r, max_cap=512.0) == 40.0
        # legacy call without the cap keeps the old (documented) behavior
        assert quantile_remaining(r) == 502.0

    def test_informative_reservation_still_used(self):
        r = self._req(100.0, predicted_len=50.0, generated=10)
        assert quantile_remaining(r, max_cap=512.0) == 90.0

    def test_pred_q_always_wins(self):
        r = self._req(512.0, predicted_len=50.0, pred_q=200.0, generated=10)
        assert quantile_remaining(r, max_cap=512.0) == 190.0

    def test_laxity_key_uses_cap(self):
        r = self._req(512.0, predicted_len=50.0, generated=10, deadline=300.0)
        assert order_key(r, "laxity", max_cap=512.0) == 300.0 - 40.0


class TestChunkAwareAdmission:
    """The admission ETA must price chunked prefill: ceil(prompt / chunk)
    ticks before the first decode token."""

    class _IdleEngine:
        def predicted_backlog(self):
            return 0.0

    def test_chunked_prefill_priced_into_eta(self):
        spec = ReplicaSpec(4, 1024, speed=1, step_token_budget=128,
                           prefill_chunk_tokens=32, page_size=16)
        req = Request(rid=0, arrival=0.0, prompt_len=100, true_len=50,
                      reserve_len=50.0, deadline=52.0)
        ac = AdmissionController(slack=1.0)
        # decode = 50 ticks, prefill = ceil(100/32) = 4 -> eta 54 > 52
        assert not ac.admit(req, self._IdleEngine(), spec, now=0.0)
        assert ac.admit(dataclasses.replace(req, deadline=54.0),
                        self._IdleEngine(), spec, now=0.0)

    def test_atomic_budget_prices_whole_budget_chunks(self):
        spec = ReplicaSpec(4, 1024, speed=1, step_token_budget=64,
                           page_size=16)
        req = Request(rid=0, arrival=0.0, prompt_len=100, true_len=50,
                      reserve_len=50.0, deadline=51.5)
        # chunk = budget = 64 -> prefill = 2 ticks -> eta 52 > 51.5
        assert not AdmissionController(slack=1.0).admit(
            req, self._IdleEngine(), spec, now=0.0)


class TestChunkAwarePredictorBatching:
    """Dispatch-time scoring rides the chunked batch-prefill: one step
    starts at most budget // chunk prompts, so the fused batch caps there."""

    def test_max_batch_capped_by_lanes(self):
        svc = PredictorService(object(), step_token_budget=64,
                               prefill_chunk_tokens=8, max_batch=512)
        assert svc.max_batch == 8
        svc = PredictorService(object(), step_token_budget=512,
                               prefill_chunk_tokens=4, max_batch=512)
        assert svc.max_batch == 128

    def test_atomic_budget_floors_at_min_bucket(self):
        svc = PredictorService(object(), step_token_budget=64, max_batch=512)
        assert svc.max_batch == 8          # 1 lane, floored at pad bucket

    def test_no_budget_keeps_max_batch(self):
        assert PredictorService(object(), max_batch=512).max_batch == 512
