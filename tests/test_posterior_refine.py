"""Mid-flight posterior length refinement: the ``PosteriorRefiner``
truncate-and-renormalize conditional, its serving integration
(``Policy.refine_every`` quantile refreshes, posterior-keyed ordering, KV
re-reservation), and the PR's bugfix regression (over-runner key collapse in
:func:`~repro.serving.scheduler.quantile_remaining`).

Covers the tentpole acceptance criteria directly:

* hypothesis property sweeps — truncate+renorm is a proper distribution
  (sums to one, zero mass at or below ``t``), posterior quantiles are
  monotone in ``t`` and never below the tokens already emitted, hazard
  corrections stay proper, and ``level_of`` inverts ``quantile``;
* ``refine_every=0`` bit-identity with pre-refinement golden rows (engine +
  cluster), so the legacy paths provably did not move;
* refine-on vec-vs-ref bit-exactness across ``refine_every`` × preempt mode
  × chunked-prefill spec × ordering, and on a stealing cluster;
* calibration — the posterior remaining-work estimate beats the static
  prompt-only estimate in MAE once survival has made the prior stale, on an
  exactly-calibrated heavy-tailed law and through a trained ProD-D head.

Runs under real ``hypothesis`` when installed, else the seeded example sweep
in ``tests/_hypothesis_compat.py``.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core.bins import decode_median, make_edges
from repro.core.online import HazardTable, PosteriorRefiner
from repro.serving.arrivals import TraceConfig, make_trace
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.predictor import PredictorService
from repro.serving.request import Request
from repro.serving.scheduler import Policy, order_key, quantile_remaining

settings.register_profile("ci", deadline=None, max_examples=12)
settings.load_profile("ci")

EDGES = np.asarray(make_edges(16, 512.0, "log"), np.float64)

# the golden serving configuration (matches the captured pre-change rows)
CFG = TraceConfig(n_requests=200, pattern="poisson", rate=1.6, seed=9,
                  model="llama", scenario="math", max_seq_len=512,
                  slo_factor=6.0, slo_floor=200.0)
POL = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
             preempt=True, preempt_factor=1.5, preempt_mode="keep")
SPEC = ReplicaSpec(max_slots=8, kv_budget=4096, speed=2,
                   prefill_tokens_per_step=64, page_size=16)
SPEC_B = ReplicaSpec(4, 2048, speed=1, prefill_tokens_per_step=32,
                     page_size=8)


def _hist(rng, conc=1.0):
    """A random 16-bin histogram (Dirichlet — strictly positive mass)."""
    return rng.dirichlet(np.full(16, float(conc)))


def _refiner(head=None, **kw):
    edges = EDGES if head is None else np.asarray(head.edges, np.float64)
    return PosteriorRefiner(edges, **kw)


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------


class TestKnobValidation:
    def test_refine_every_validated(self):
        with pytest.raises(ValueError, match="refine_every"):
            Policy("fcfs", refine_every=-1)
        with pytest.raises(ValueError, match="refine_every"):
            Policy("fcfs", refine_every=2.5)
        assert Policy("fcfs").refine_every == 0
        assert Policy("fcfs", refine_every=16).refine_every == 16

    def test_engine_requires_refiner_when_refining(self):
        pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512,
                     refine_every=16)
        with pytest.raises(ValueError, match="PosteriorRefiner"):
            SimEngine(spec=SPEC, policy=pol)
        # refine off: a refiner is optional and unused
        SimEngine(spec=SPEC, policy=Policy("fcfs"), refiner=_refiner())

    def test_refiner_validates_inputs(self):
        with pytest.raises(ValueError, match="edges"):
            PosteriorRefiner(np.array([4.0]))
        with pytest.raises(ValueError, match="work_quantile"):
            PosteriorRefiner(EDGES, work_quantile=1.0)
        assert _refiner().cap == float(EDGES[-1])


# ---------------------------------------------------------------------------
# truncate-renorm posterior: property sweep
# ---------------------------------------------------------------------------


class TestRefinerProperties:
    @given(st.integers(0, 100_000), st.floats(0.0, 600.0),
           st.floats(0.2, 5.0))
    def test_condition_is_proper_distribution(self, seed, t, conc):
        """P[L ∈ bin | L > t] sums to one, is non-negative, and puts zero
        mass on bins entirely at or below t — for every t, including past
        the support (degenerate point mass at the cap)."""
        rz = _refiner()
        p = _hist(np.random.default_rng(seed), conc)
        cond = rz.condition(p, t)
        assert np.all(cond >= 0.0)
        assert cond.sum() == pytest.approx(1.0, abs=1e-9)
        if rz.survivor(p, t) > 1e-12:
            assert np.all(cond[EDGES[1:] <= t] == 0.0)
        else:
            # past the support: explicit point mass at the cap, never NaN
            assert cond[-1] == 1.0 and np.all(cond[:-1] == 0.0)

    @given(st.integers(0, 100_000), st.floats(0.0, 550.0),
           st.floats(1.0, 80.0))
    def test_quantiles_monotone_in_t_and_never_below_progress(
            self, seed, t, dt):
        """Posterior total-length quantiles are ≥ t, monotone in the CDF
        level, weakly monotone in t (conditioning on longer survival can
        only push the estimate up), and clamped into [t, max(cap, t+1)]."""
        rz = _refiner()
        p = _hist(np.random.default_rng(seed))
        lo_t, hi_t = float(t), float(t + dt)
        q50a, q90a = rz.quantiles(p, lo_t, (0.5, 0.9))
        q50b, q90b = rz.quantiles(p, hi_t, (0.5, 0.9))
        assert q50a <= q90a and q50b <= q90b        # monotone in level
        assert q50a >= lo_t and q90a >= lo_t        # never below progress
        assert q50a <= q50b + 1e-9 and q90a <= q90b + 1e-9  # monotone in t
        cap = max(rz.cap, hi_t + 1.0)
        assert q90b <= cap

    @given(st.integers(0, 100_000))
    def test_t_zero_matches_marginal_decode(self, seed):
        """At t = 0 the posterior is the dispatch histogram: the refined
        median must agree with the marginal CDF-crossing decode
        (:func:`repro.core.bins.decode_median`)."""
        import jax.numpy as jnp

        rz = _refiner()
        p = _hist(np.random.default_rng(seed))
        ours = rz.quantile(p, 0.0, 0.5)
        ref = float(decode_median(jnp.asarray(p[None, :], jnp.float32),
                                  jnp.asarray(EDGES, jnp.float32))[0])
        assert ours == pytest.approx(ref, rel=1e-4)

    @given(st.integers(0, 100_000), st.sampled_from([0.25, 0.5, 0.75, 0.9]))
    def test_level_of_inverts_quantile(self, seed, q):
        """``level_of`` recovers the CDF level a marginal quantile was cut
        at — the effective-level recovery the conformal-on-posterior
        reservation re-cut relies on."""
        rz = _refiner()
        p = _hist(np.random.default_rng(seed))
        v = rz.quantile(p, 0.0, q)
        assert rz.level_of(p, v) == pytest.approx(q, abs=1e-6)

    def test_hazard_identity_correction_is_noop(self):
        """A hazard table whose grid rows equal naive truncate-renorm of its
        own prior corrects by exactly 1 — hazard refinement degrades
        gracefully to pure renormalization when the head learns nothing."""
        rng = np.random.default_rng(4)
        prior = _hist(rng)
        plain = _refiner()
        grid = np.array([0.0, 16.0, 64.0, 256.0])
        hz = HazardTable(ts=grid,
                         probs=np.stack([plain.condition(prior, t)
                                         for t in grid]),
                         prior=prior)
        corrected = PosteriorRefiner(EDGES, hazard=hz)
        p = _hist(rng)
        for t in grid:
            np.testing.assert_allclose(corrected.condition(p, t),
                                       plain.condition(p, t), atol=1e-12)
            assert corrected.quantile(p, t, 0.9) == \
                pytest.approx(plain.quantile(p, t, 0.9), abs=1e-9)

    @given(st.integers(0, 100_000), st.floats(0.0, 600.0))
    def test_hazard_correction_stays_proper_and_clipped(self, seed, t):
        """Arbitrary (even adversarial) hazard rows still yield a proper
        conditional with support above t, and the multiplicative correction
        honors the clip range."""
        rng = np.random.default_rng(seed)
        prior = _hist(rng)
        grid = np.array([0.0, 32.0, 128.0])
        hz = HazardTable(ts=grid, probs=np.stack([_hist(rng)
                                                  for _ in grid]),
                         prior=prior, clip=(0.25, 4.0))
        rz = PosteriorRefiner(EDGES, hazard=hz)
        p = _hist(rng)
        cond = rz.condition(p, t)
        assert cond.sum() == pytest.approx(1.0, abs=1e-9)
        assert np.all(cond >= 0.0)
        if rz.survivor(p, t) > 1e-12:
            assert np.all(cond[EDGES[1:] <= t] == 0.0)
        # correction is bounded: corrected mass within clip × plain mass
        plain = _refiner()._mass(p, t)
        m = rz._mass(p, t)
        live = plain > 0
        assert np.all(m[live] <= plain[live] * 4.0 + 1e-12)
        assert np.all(m[live] >= plain[live] * 0.25 - 1e-12)


# ---------------------------------------------------------------------------
# refine off: bit-identity with the pre-refinement goldens
# ---------------------------------------------------------------------------


class TestGoldenBitIdentity:
    """``refine_every=0`` must leave every legacy number untouched.

    The expected values are the exact rows this configuration produced
    BEFORE the refinement code existed (captured at the pre-change commit,
    ProD-D head service + preempt-keep SRTF). Equality is exact — no
    tolerance."""

    ENGINE_GOLDEN = dict(
        makespan=1176.0, mean_latency=504.9656919435099,
        p50_latency=487.38302197615576, p90_latency=950.8028320924847,
        p99_latency=1062.3470379757387, mean_wait=436.71757164275806,
        throughput=14.763605442176871, kv_waste_ratio=0.4211156676375901,
        overflow_events=12, peak_reserved=3600, completed=133,
        preemptions=0, timed_out=67, slo_violations=11,
        goodput=13.176020408163266, page_size=16,
        occupancy=0.6933460884353742, frag_ratio=0.018992890541162044,
        prefill_ticks=358, mean_ttft=440.4093009660663,
        p50_ttft=425.7189024705044, p90_ttft=883.817477813265,
        p99_ttft=970.9760775703354,
    )
    CLUSTER_GOLDEN = dict(
        makespan=1207.0, mean_latency=516.1326378070175,
        p50_latency=498.9039085252852, p90_latency=1005.5564971538777,
        p99_latency=1082.3104840653002, mean_wait=431.25533719352063,
        throughput=18.31980115990058, kv_waste_ratio=0.42099932670474405,
        overflow_events=14, completed=163, timed_out=37, slo_violations=19,
        goodput=15.580778790389395, stolen=3, steal_pages=40,
        balance=1.5973227206946454, occupancy=0.7097896817177575,
        frag_ratio=0.015503649169095857, prefill_ticks=517,
        mean_ttft=435.4271163346249, p50_ttft=412.25316797578125,
        p90_ttft=888.3518077823148, p99_ttft=978.5226097580422,
    )

    def test_engine_row_unchanged(self, shared_head):
        svc = PredictorService(shared_head, window=8.0)
        eng = SimEngine(spec=SPEC, policy=POL, predictor=svc,
                        vectorized=True)
        stats = eng.run(make_trace(CFG))
        row = stats.row()
        for k, v in self.ENGINE_GOLDEN.items():
            assert row[k] == v, (k, row[k], v)
        assert stats.refine_events == 0
        assert stats.refine_shrinks == 0 and stats.refine_grows == 0

    def test_cluster_row_unchanged(self, shared_head):
        svc = PredictorService(shared_head, window=8.0)
        cl = Cluster((SPEC, SPEC_B), POL, router="psq", predictor=svc,
                     rebalance_every=64, steal="quantile")
        stats = cl.run(make_trace(CFG))
        row = stats.row()
        for k, v in self.CLUSTER_GOLDEN.items():
            assert row[k] == v, (k, row[k], v)
        assert stats.refine_events == 0


# ---------------------------------------------------------------------------
# refine on: vec-vs-ref bit-exactness
# ---------------------------------------------------------------------------


TRACE_CFG_SMALL = TraceConfig(n_requests=120, pattern="poisson", rate=1.2,
                              seed=5, model="llama", scenario="math",
                              max_seq_len=512, slo_factor=6.0,
                              slo_floor=200.0)


class TestVecRefBitExactness:
    """Refine ticks are evented (like budget-constrained ticks): the
    vectorized leap path must land on exactly the ticks the per-slot
    reference loop refines at, so refined runs stay bit-exact."""

    def _run(self, shared_head, pol, spec, vectorized):
        svc = PredictorService(shared_head, window=8.0)
        eng = SimEngine(spec=spec, policy=pol, predictor=svc,
                        vectorized=vectorized,
                        refiner=_refiner(shared_head))
        stats = eng.run(make_trace(TRACE_CFG_SMALL))
        return stats.row(), stats.refine_events

    @settings(max_examples=8)
    @given(st.sampled_from([1, 4, 16, 48]),
           st.sampled_from(["keep", "recompute"]),
           st.sampled_from(["legacy", "budget", "chunk"]),
           st.sampled_from(["srtf_pred", "laxity"]))
    def test_vec_matches_ref(self, every, pmode, variant, order):
        pol = Policy(order, "quantile", quantile=0.9, max_seq_len=512,
                     preempt=True, preempt_factor=1.5, preempt_mode=pmode,
                     refine_every=every)
        kw = dict(max_slots=8, kv_budget=4096, speed=2, page_size=16)
        if variant == "legacy":
            spec = ReplicaSpec(prefill_tokens_per_step=64, **kw)
        elif variant == "budget":
            spec = ReplicaSpec(step_token_budget=96, **kw)
        else:
            spec = ReplicaSpec(step_token_budget=96,
                               prefill_chunk_tokens=32, **kw)
        head = self._head
        a, ev_a = self._run(head, pol, spec, True)
        b, ev_b = self._run(head, pol, spec, False)
        assert a == b
        assert ev_a == ev_b > 0

    @pytest.fixture(autouse=True, scope="class")
    def _bind_head(self, request, shared_head):
        # @given-wrapped tests cannot take pytest fixtures as extra
        # arguments, so the session head is bound through the class
        request.cls._head = shared_head

    def test_cluster_with_stealing_matches(self, shared_head):
        pol = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
                     preempt=True, preempt_factor=1.5, preempt_mode="keep",
                     refine_every=16)
        rows = {}
        for vec in (True, False):
            svc = PredictorService(shared_head, window=8.0)
            cl = Cluster((SPEC, SPEC_B), pol, router="psq", predictor=svc,
                         rebalance_every=64, steal="quantile",
                         vectorized=vec, refiner=_refiner(shared_head))
            stats = cl.run(make_trace(CFG))
            rows[vec] = (stats.row(), stats.refine_events)
        assert rows[True] == rows[False]
        assert rows[True][1] > 0

    def test_refine_on_drains_kv_pool(self, shared_head):
        """After a refined run every page is back in the pool — shrink /
        grow re-reservations never strand pages (engine-level mirror of the
        allocator differential test)."""
        pol = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
                     preempt=True, preempt_factor=1.5, preempt_mode="keep",
                     refine_every=8)
        svc = PredictorService(shared_head, window=8.0)
        eng = SimEngine(spec=SPEC, policy=pol, predictor=svc,
                        vectorized=True, refiner=_refiner(shared_head))
        stats = eng.run(make_trace(TRACE_CFG_SMALL))
        assert stats.refine_events > 0
        assert eng.kv.reserved_now == 0 and eng.kv.logical_now == 0
        assert eng.kv.pages_free == eng.kv.pages_total
        assert eng.kv.reserved == {}


# ---------------------------------------------------------------------------
# calibration: posterior beats the prompt-only head once the prior is stale
# ---------------------------------------------------------------------------


class TestCalibration:
    def test_posterior_beats_prompt_only_on_calibrated_law(self):
        """On an exactly calibrated heavy-tailed law (every request carries
        the true histogram), the posterior median's remaining-work MAE
        strictly beats the static prompt-only median from t ≥ 32 on — and
        already at t = 16, since the law's median sits near 25."""
        rng = np.random.default_rng(7)
        lengths = np.clip(rng.lognormal(np.log(25.0), 1.1, size=6000),
                          1.0, 512.0)
        p, _ = np.histogram(lengths, bins=EDGES)
        p = p / p.sum()
        rz = _refiner()
        m0 = rz.quantile(p, 0.0, 0.5)
        for t in (16.0, 32.0, 64.0, 128.0):
            alive = lengths[lengths > t]
            post = np.abs((rz.quantile(p, t, 0.5) - t) - (alive - t)).mean()
            prompt = np.abs(max(m0 - t, 1.0) - (alive - t)).mean()
            assert post < prompt, (t, post, prompt)

    def test_posterior_beats_prompt_only_with_trained_head(self, shared_head):
        """Through the trained ProD-D head on a llama/math trace the
        crossover sits past the predicted medians (~40–190): deep into
        decode (t = 128) the truncated posterior must beat the stale
        dispatch-time median by a wide margin."""
        cfg = TraceConfig(n_requests=400, pattern="poisson", rate=1.6,
                          seed=21, model="llama", scenario="math",
                          max_seq_len=512, slo_factor=6.0, slo_floor=200.0)
        reqs = make_trace(cfg)
        svc = PredictorService(shared_head, window=8.0)
        svc.annotate(reqs, Policy("fcfs", "quantile", quantile=0.9,
                                  max_seq_len=512))
        rz = _refiner(shared_head)
        t = 128.0
        alive = [r for r in reqs if r.true_len > t]
        assert len(alive) > 50
        post = np.mean([abs((rz.quantile(r.pred_probs, t, 0.5) - t)
                            - (r.true_len - t)) for r in alive])
        prompt = np.mean([abs(max(r.predicted_len - t, 1.0)
                              - (r.true_len - t)) for r in alive])
        assert post < prompt * 0.9, (post, prompt)


# ---------------------------------------------------------------------------
# bugfix regression: over-runner key collapse
# ---------------------------------------------------------------------------


class TestOverrunnerRegression:
    """Bugfix: ``quantile_remaining``'s ``max(base - generated, 1.0)`` floor
    collapsed every request that outlived its dispatch quantile onto the
    same key (1.0), so SRTF victim choice, least-laxity ordering, and
    quantile stealing degenerated to tie-break order among over-runners.
    Posterior conditioning keeps them mutually ordered by their tails."""

    def _overrunners(self, n=4):
        rng = np.random.default_rng(3)
        reqs = []
        for i in range(n):
            r = Request(rid=i, arrival=0.0, prompt_len=8, true_len=400,
                        deadline=600.0)
            r.predicted_len = 40.0 + 5 * i
            r.pred_q = 90.0 + 10 * i
            r.reserve_len = r.pred_q
            r.pred_probs = rng.dirichlet(np.ones(16) * (0.5 + i))
            r.generated = 200 + 10 * i       # far past its q0.9
            reqs.append(r)
        return reqs

    def test_overrunner_keys_collapse_without_refiner(self):
        """Pins the pre-fix behavior: with no refiner every over-runner
        keys to exactly the 1.0 floor — indistinguishable."""
        keys = [quantile_remaining(r) for r in self._overrunners()]
        assert keys == [1.0] * len(keys)

    def test_refiner_keeps_overrunner_keys_distinct(self):
        rz = _refiner()
        keys = [quantile_remaining(r, refiner=rz)
                for r in self._overrunners()]
        assert all(k > 1.0 for k in keys)
        assert len(set(keys)) == len(keys)          # mutually ordered
        # each key is the posterior work-quantile of the *remaining* tokens
        for r, k in zip(self._overrunners(), keys):
            want = rz.quantile(r.pred_probs, float(r.generated),
                               rz.work_quantile) - r.generated
            assert k == pytest.approx(want)

    def test_laxity_order_key_uses_posterior(self):
        rz = _refiner()
        keys_off = {order_key(r, "laxity", max_cap=512.0)
                    for r in self._overrunners()}
        keys_on = {order_key(r, "laxity", max_cap=512.0, refiner=rz)
                   for r in self._overrunners()}
        assert len(keys_off) == 1                   # pre-fix: all tied
        assert len(keys_on) == len(self._overrunners())

    def test_normal_runners_unaffected(self):
        """The posterior path only engages on over-runners: a request still
        below its dispatch quantile keys identically with and without the
        refiner."""
        r = Request(rid=0, arrival=0.0, prompt_len=8, true_len=400)
        r.pred_q = 300.0
        r.pred_probs = np.full(16, 1 / 16)
        r.generated = 100
        assert quantile_remaining(r) == \
            quantile_remaining(r, refiner=_refiner()) == 200.0
