"""Predictor-in-the-loop: the trained ProD-D head serving the cluster
(PredictorService batched/jitted/cached dispatch-time inference, the
PerfectOracle upper bound), deadline-aware EDF / least-laxity orderings, and
dedicated LatentOracle quantile-calibration coverage."""

import numpy as np
import pytest

from repro.data.lengths import (sample_prompt_latents,
                                true_conditional_median)
from repro.data.scenarios import get_spec
from repro.serving.arrivals import (LatentOracle, TraceConfig, corrupt_latents,
                                    make_trace)
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.predictor import PerfectOracle, PredictorService
from repro.serving.request import Request
from repro.serving.scheduler import (ORDERINGS, Policy, order_key,
                                     quantile_remaining)

TRACE_CFG = TraceConfig(n_requests=300, pattern="bursty", rate=1.5, seed=11,
                        model="llama", scenario="math", max_seq_len=512,
                        slo_factor=3.0, slo_floor=50.0)
QPOL = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)


@pytest.fixture(scope="module")
def trace():
    return make_trace(TRACE_CFG)


@pytest.fixture(scope="module")
def head(shared_head):
    """The session-scoped ProD-D head (conftest ``shared_head``) — identical
    weights to ``fit_trace_head(TRACE_CFG, n_train=400, r=6, n_bins=16,
    hidden=32, seed=5)`` since the fit ignores the trace pattern/seed."""
    return shared_head


def _svc(head, **kw):
    kw.setdefault("window", 8.0)
    return PredictorService(head, **kw)


# ---------------------------------------------------------------------------
# PredictorService: batched dispatch-time inference
# ---------------------------------------------------------------------------


class TestPredictorService:
    def test_annotates_all_requests(self, trace, head):
        reqs = [r.fresh_copy() for r in trace]
        svc = _svc(head)
        svc.annotate(reqs, QPOL)
        for r in reqs:
            assert r.predicted_len is not None and r.predicted_len > 0
            assert r.pred_q is not None
            assert r.reserve_len is not None
            assert 8.0 <= r.reserve_len <= QPOL.max_seq_len
            assert r.pred_probs is not None and r.pred_probs.shape == (16,)
            np.testing.assert_allclose(r.pred_probs.sum(), 1.0, rtol=1e-5)
            # q0.9 of the predictive distribution sits at/above its median
            assert r.pred_q >= r.predicted_len - 1e-6
        assert svc.stats.requests == len(reqs)
        assert svc.stats.batches > 0

    def test_matches_unbatched_protocol(self, trace, head):
        """Window batching + padding + caching must not change predictions:
        the attached medians equal the raw predict() over stacked features."""
        reqs = [r.fresh_copy() for r in trace]
        svc = _svc(head)
        svc.annotate(reqs, QPOL)
        phi = np.stack([r.phi for r in reqs])
        med = PredictorService(head).predict(phi)
        q90 = PredictorService(head).quantile(phi, 0.9)
        np.testing.assert_allclose([r.predicted_len for r in reqs], med,
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose([r.pred_q for r in reqs], q90,
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("kw", [dict(window=2.0), dict(window=256.0),
                                    dict(max_batch=16), dict(cache_size=0)])
    def test_windowing_invariant(self, trace, head, kw):
        """Different dispatch windows / batch caps / cache settings are pure
        amortization knobs — annotated values stay identical."""
        base = [r.fresh_copy() for r in trace]
        _svc(head).annotate(base, QPOL)
        alt = [r.fresh_copy() for r in trace]
        _svc(head, **kw).annotate(alt, QPOL)
        for a, b in zip(base, alt):
            assert a.predicted_len == pytest.approx(b.predicted_len, rel=1e-6)
            assert a.pred_q == pytest.approx(b.pred_q, rel=1e-6)
            assert a.reserve_len == pytest.approx(b.reserve_len, rel=1e-6)

    def test_lru_cache_hits_and_dedupe(self, trace, head):
        reqs = [r.fresh_copy() for r in trace[:64]]
        svc = _svc(head)
        svc.annotate(reqs, QPOL)
        assert svc.stats.cache_hits == 0
        first = [(r.predicted_len, r.reserve_len) for r in reqs]
        again = [r.fresh_copy() for r in trace[:64]]
        svc.annotate(again, QPOL)     # every feature vector seen already
        assert svc.stats.cache_hits == 64
        assert svc.stats.scored == 64          # no head re-evaluation
        assert [(r.predicted_len, r.reserve_len) for r in again] == first

    def test_duplicate_features_scored_once(self, head):
        phi = np.array([0.5, 0.15, 0.02, 2.5])
        reqs = [Request(rid=i, arrival=float(i) * 0.01, prompt_len=16,
                        true_len=100, phi=phi) for i in range(32)]
        svc = _svc(head)
        svc.annotate(reqs, QPOL)
        assert svc.stats.scored == 1           # in-window dedupe
        assert len({r.predicted_len for r in reqs}) == 1

    def test_requires_features(self, head):
        r = Request(rid=0, arrival=0.0, prompt_len=8, true_len=10)
        with pytest.raises(ValueError):
            _svc(head).annotate([r], QPOL)

    def test_reserve_policies(self, trace, head):
        for reserve in ("max", "predicted", "quantile", "oracle"):
            pol = Policy("fcfs", reserve, quantile=0.9, max_seq_len=512)
            reqs = [r.fresh_copy() for r in trace[:32]]
            _svc(head).annotate(reqs, pol)
            for r in reqs:
                if reserve == "max":
                    assert r.reserve_len == 512.0
                elif reserve == "oracle":
                    assert r.reserve_len == float(
                        min(max(r.true_len, 8.0), 512))
                else:
                    assert 8.0 <= r.reserve_len <= 512.0


class TestPerfectOracle:
    def test_annotates_realized_lengths(self, trace):
        reqs = [r.fresh_copy() for r in trace[:50]]
        PerfectOracle().annotate(reqs, QPOL)
        for r in reqs:
            assert r.predicted_len == float(r.true_len)
            assert r.pred_q == float(r.true_len)
            assert r.reserve_len == float(min(max(r.true_len, 8.0), 512))

    def test_max_reserve_still_reserves_cap(self, trace):
        reqs = [r.fresh_copy() for r in trace[:10]]
        PerfectOracle().annotate(reqs, Policy("fcfs", "max", max_seq_len=256))
        assert all(r.reserve_len == 256.0 for r in reqs)

    def test_perfect_cluster_completes(self, trace):
        st = Cluster.uniform(2, 4, 2 * (256 + 512), QPOL, router="psq",
                             predictor=PerfectOracle()).run(trace)
        assert st.completed + st.timed_out + st.dropped == len(trace)


# ---------------------------------------------------------------------------
# deadline-aware orderings: EDF and least-laxity
# ---------------------------------------------------------------------------


def _req(rid, arrival=0.0, deadline=None, pred_q=None, true_len=50):
    return Request(rid=rid, arrival=arrival, prompt_len=8, true_len=true_len,
                   predicted_len=float(true_len), reserve_len=64.0,
                   deadline=deadline, pred_q=pred_q)


class TestOrderKeys:
    def test_all_orderings_have_keys(self):
        r = _req(0, deadline=100.0, pred_q=40.0)
        for o in ORDERINGS:
            assert np.isfinite(order_key(r, o))

    def test_edf_keys_on_deadline(self):
        assert order_key(_req(0, deadline=10.0), "edf") == 10.0
        assert order_key(_req(0, deadline=None), "edf") == float("inf")

    def test_laxity_key_is_deadline_minus_work(self):
        r = _req(0, deadline=100.0, pred_q=40.0)
        assert order_key(r, "laxity") == 100.0 - 40.0
        assert order_key(_req(0, deadline=None, pred_q=40.0),
                         "laxity") == float("inf")

    def test_quantile_remaining_fallbacks(self):
        r = _req(0, pred_q=80.0)
        r.generated = 30
        assert quantile_remaining(r) == 50.0
        r.pred_q = None                        # falls back to reservation
        assert quantile_remaining(r) == 64.0 - 30
        r.reserve_len = None                   # then to the point prediction
        assert quantile_remaining(r) == 20.0
        assert order_key(r, "fcfs") == 0.0     # unrelated orders unaffected

    def test_unknown_order_raises(self):
        with pytest.raises(ValueError):
            order_key(_req(0), "lifo")


class TestDeadlineOrderingSemantics:
    def _serve_order(self, order, reqs):
        pol = Policy(order, "quantile", quantile=0.9, max_seq_len=512)
        eng = SimEngine(policy=pol, spec=ReplicaSpec(1, 4096))
        eng.run(reqs)
        return [r.rid for r in sorted(eng.done, key=lambda r: r.t_start)]

    def test_edf_runs_earliest_deadline_first(self):
        # rid 1 arrives marginally later but its deadline is far tighter
        reqs = [_req(0, arrival=0.0, deadline=10_000.0),
                _req(1, arrival=0.0, deadline=500.0)]
        assert self._serve_order("edf", reqs) == [1, 0]
        assert self._serve_order("fcfs", reqs) == [0, 1]

    def test_edf_no_deadline_runs_last(self):
        reqs = [_req(0, arrival=0.0, deadline=None),
                _req(1, arrival=0.0, deadline=9_000.0)]
        assert self._serve_order("edf", reqs) == [1, 0]

    def test_laxity_prefers_larger_predicted_work(self):
        # equal deadlines: the request predicted to need more tokens has the
        # least laxity and must start first
        reqs = [_req(0, arrival=0.0, deadline=1000.0, pred_q=20.0),
                _req(1, arrival=0.0, deadline=1000.0, pred_q=400.0,
                     true_len=60)]
        assert self._serve_order("laxity", reqs) == [1, 0]

    def test_deadline_ordering_cuts_slo_misses(self):
        """Bursty trace at ~0.8 load with feasible per-class SLOs: transient
        backlog builds during bursts, and triaging it by deadline (EDF) or
        laxity beats FCFS on deadline misses (timed out + late finishes).
        Needs a MIXED trace — in a single-scenario trace every request gets
        the same SLO budget, so EDF degenerates to FCFS exactly."""
        from repro.serving.arrivals import mean_true_length, stable_rate

        probe = make_trace(TraceConfig(n_requests=1000, rate=1.0, seed=11,
                                       model="mix", scenario="mix",
                                       max_seq_len=512))
        rate = stable_rate(2, 8, mean_true_length(probe), 0.8)
        reqs = make_trace(TraceConfig(
            n_requests=800, pattern="bursty", rate=rate, seed=11,
            model="mix", scenario="mix", max_seq_len=512,
            slo_factor=10.0, slo_floor=300.0))

        def misses(order):
            pol = Policy(order, "quantile", quantile=0.9, max_seq_len=512)
            st = Cluster.uniform(2, 8, 4 * (256 + 512), pol, router="psq",
                                 predictor=LatentOracle()).run(reqs)
            return st.timed_out + st.slo_violations

        fcfs = misses("fcfs")
        assert misses("edf") < fcfs
        assert misses("laxity") < fcfs


class TestVecRefBitExactness:
    """Acceptance: the event-leap fast path stays bit-identical on the new
    predictor (trained head via PredictorService, PerfectOracle) and the new
    ordering (edf, laxity) paths — engine and cluster level."""

    def _rows(self, maker, reqs):
        out = []
        for vec in (True, False):
            obj = maker(vec)
            st = obj.run(reqs)
            eng = obj.engines if hasattr(obj, "engines") else [obj]
            done = sorted((r.rid, r.t_start, r.t_finish)
                          for e in eng for r in e.done)
            out.append((st.row(), done))
        return out

    @pytest.mark.parametrize("order", ["edf", "laxity"])
    def test_engine_orderings(self, trace, order):
        pol = Policy(order, "quantile", quantile=0.9, max_seq_len=512)
        a, b = self._rows(
            lambda vec: SimEngine(policy=pol, predictor=LatentOracle(),
                                  vectorized=vec,
                                  spec=ReplicaSpec(4, 2 * (256 + 512),
                                                   speed=2,
                                                   prefill_tokens_per_step=64)),
            trace)
        assert a == b

    @pytest.mark.parametrize("order", ["fcfs", "edf", "laxity"])
    def test_cluster_trained_head(self, trace, head, order):
        pol = Policy(order, "quantile", quantile=0.9, max_seq_len=512)
        a, b = self._rows(
            lambda vec: Cluster.uniform(3, 4, 2 * (256 + 512), pol,
                                        router="psq",
                                        predictor=_svc(head),
                                        vectorized=vec),
            trace)
        assert a == b

    def test_cluster_perfect_with_stealing(self, trace):
        specs = (ReplicaSpec(4, 2 * (256 + 512), speed=2),
                 ReplicaSpec(2, 256 + 512, speed=1))
        pol = Policy("laxity", "quantile", quantile=0.9, max_seq_len=512)
        a, b = self._rows(
            lambda vec: Cluster(specs, pol, router="psq",
                                predictor=PerfectOracle(), vectorized=vec,
                                rebalance_every=25, steal="quantile"),
            trace)
        assert a == b

    def test_trained_head_deterministic_replay(self, trace, head):
        rows = [Cluster.uniform(2, 4, 2 * (256 + 512), QPOL, router="psq",
                                predictor=_svc(head)).run(trace).row()
                for _ in range(2)]
        assert rows[0] == rows[1]


# ---------------------------------------------------------------------------
# fused multi-quantile head decode
# ---------------------------------------------------------------------------


class TestFusedQuantiles:
    def test_matches_median_path_and_monotone(self, head):
        import jax.numpy as jnp
        phi = jnp.asarray(np.random.default_rng(0).normal(
            size=(23, 4)), jnp.float32)
        probs, quants = head.quantiles(phi, (0.25, 0.5, 0.9, 0.99))
        med = head.predict(phi)
        np.testing.assert_allclose(np.asarray(quants[:, 1]), np.asarray(med),
                                   rtol=1e-5, atol=1e-4)
        q = np.asarray(quants)
        assert np.all(np.diff(q, axis=1) >= -1e-5)   # monotone in the level
        np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)

    def test_interpret_kernel_matches_xla(self, head):
        import jax.numpy as jnp
        from repro.kernels import ops
        phi = jnp.asarray(np.random.default_rng(1).normal(
            size=(9, 4)), jnp.float32)
        p = head.params
        qs = jnp.asarray([0.5, 0.9], jnp.float32)
        px, qx = ops.prod_head(phi, p["w1"], p["b1"], p["w2"], p["b2"],
                               head.edges, qs=qs, impl="xla")
        pi, qi = ops.prod_head(phi, p["w1"], p["b1"], p["w2"], p["b2"],
                               head.edges, qs=qs, block_b=4, impl="interpret")
        np.testing.assert_allclose(np.asarray(px), np.asarray(pi),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(qx), np.asarray(qi),
                                   rtol=1e-4, atol=1e-3)

    def test_q_one_clamps_to_last_bin_in_both_impls(self, head):
        """q=1.0 where float32 CDF rounding never crosses: both impls must
        clamp to the LAST bin (never silently fall to bin 0 and
        under-reserve)."""
        import jax.numpy as jnp
        from repro.kernels import ops
        phi = jnp.asarray(np.random.default_rng(2).normal(
            size=(16, 4)), jnp.float32)
        p = head.params
        qs = jnp.asarray([1.0], jnp.float32)
        lo = float(head.edges[-2])     # any q=1.0 answer lives in the last bin
        for impl in ("xla", "interpret"):
            _, q1 = ops.prod_head(phi, p["w1"], p["b1"], p["w2"], p["b2"],
                                  head.edges, qs=qs, block_b=8, impl=impl)
            assert np.all(np.asarray(q1) >= lo), impl


# ---------------------------------------------------------------------------
# LatentOracle calibration (satellite: direct coverage, not via cluster runs)
# ---------------------------------------------------------------------------


class TestLatentOracleCalibration:
    def test_quantile_monotone_in_level(self):
        rng = np.random.default_rng(3)
        spec = get_spec("qwen", "longseq")
        lat = sample_prompt_latents(rng, spec.law, 400)
        phi = corrupt_latents(rng, lat, spec, "last")
        o = LatentOracle()
        qs = [o.quantile(phi, q) for q in (0.5, 0.75, 0.9, 0.99)]
        for lo, hi in zip(qs, qs[1:]):
            assert np.all(lo <= hi + 1e-6)
        assert np.all(qs[0] > 0)

    def test_median_error_shrinks_with_feature_noise(self):
        """The oracle's whole point: its error IS the feature noise. MAE
        against the true conditional median must shrink monotonically as the
        latent corruption goes to zero, and vanish at zero."""
        rng = np.random.default_rng(4)
        spec = get_spec("llama", "math")
        lat = sample_prompt_latents(rng, spec.law, 2000)
        truth = true_conditional_median(lat)
        o = LatentOracle()
        maes = []
        for sigma in (0.6, 0.3, 0.1, 0.0):
            noisy = lat.copy()
            noisy[:, 0] += sigma * rng.standard_normal(len(lat))
            maes.append(float(np.mean(np.abs(o.predict(noisy) - truth))))
        assert maes[0] > maes[1] > maes[2] > maes[3]
        assert maes[-1] == pytest.approx(0.0, abs=1e-9)

    def test_view_informativeness_ordering(self):
        """Feature views order prediction error the way the paper calibrates
        them: last < mean < proxy < entropy."""
        spec = get_spec("qwen", "chat")
        lat = sample_prompt_latents(np.random.default_rng(5), spec.law, 3000)
        truth = true_conditional_median(lat)
        o = LatentOracle()
        maes = []
        for view in ("last", "mean", "proxy", "entropy"):
            rng = np.random.default_rng(6)    # same noise draws per view
            phi = corrupt_latents(rng, lat, spec, view)
            maes.append(float(np.mean(np.abs(o.predict(phi) - truth))))
        assert maes == sorted(maes)
