"""Property-based invariants for the KV reservation allocator.

Random reserve/grow/use/free/preempt op sequences, replayed against
:class:`~repro.serving.kvcache.KVCacheManager` with a shadow model, must
never exceed the pool, never corrupt the scalar counter on double-free, and
keep the usage integral below the reservation integral — the invariants the
engine's waste metric and admission control rest on. Runs under real
``hypothesis`` when installed, else the seeded example sweep in
``tests/_hypothesis_compat.py``.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.serving.kvcache import KVCacheManager

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")

BUDGET = 1000


def _apply_ops(rng: np.random.Generator, n_ops: int, budget: int = BUDGET):
    """Engine-shaped random op stream: admit / grow / use (within the
    reservation, as the engine guarantees) / release / tick. Yields the
    manager after every op so the caller can assert invariants."""
    kv = KVCacheManager(budget_tokens=budget)
    live = []
    next_rid = 0
    for _ in range(n_ops):
        op = int(rng.integers(0, 5))
        if op == 0:                                   # admit
            need = int(rng.integers(1, budget // 2))
            if kv.admit(next_rid, need):
                live.append(next_rid)
            next_rid += 1
        elif op == 1 and live:                        # grow
            rid = live[int(rng.integers(0, len(live)))]
            kv.grow(rid, int(rng.integers(1, 200)))
        elif op == 2 and live:                        # use within reservation
            rid = live[int(rng.integers(0, len(live)))]
            room = kv.reserved[rid] - kv.used.get(rid, 0)
            if room > 0:
                kv.use(rid, int(rng.integers(1, room + 1)))
        elif op == 3 and live:                        # release (preempt/finish)
            rid = live.pop(int(rng.integers(0, len(live))))
            kv.release(rid)
        else:                                         # tick: integrals advance
            kv.tick()
        yield kv, live


class TestKVCacheProperties:
    @given(st.integers(0, 100_000), st.integers(20, 120))
    def test_pool_never_exceeded_and_counters_consistent(self, seed, n_ops):
        rng = np.random.default_rng(seed)
        for kv, live in _apply_ops(rng, n_ops):
            assert 0 <= kv.reserved_now <= kv.budget_tokens
            assert kv.reserved_now == sum(kv.reserved.values())
            assert set(kv.reserved) == set(live)
            assert kv.peak_reserved <= kv.budget_tokens
            assert kv.reserved_now <= kv.peak_reserved
            for rid, used in kv.used.items():
                assert 0 <= used <= kv.reserved[rid]

    @given(st.integers(0, 100_000), st.integers(20, 120))
    def test_usage_integral_bounded_by_reservation_integral(self, seed, n_ops):
        """total_used_steps <= total_reserved_steps at every point: a token
        can only be used inside a reservation, so the per-tick usage sum can
        never exceed the per-tick reservation sum."""
        rng = np.random.default_rng(seed)
        for kv, _ in _apply_ops(rng, n_ops):
            assert kv.total_used_steps <= kv.total_reserved_steps
            assert 0.0 <= kv.waste_ratio <= 1.0

    @given(st.integers(0, 100_000))
    def test_double_free_is_harmless(self, seed):
        """Releasing a rid twice (or one never admitted) must not corrupt the
        scalar counter or go negative — the engine relies on release being
        idempotent across preempt/evict races."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=BUDGET)
        rids = []
        for rid in range(8):
            if kv.admit(rid, int(rng.integers(1, 200))):
                rids.append(rid)
        before = kv.reserved_now
        assert before == sum(kv.reserved.values())
        victim = rids[int(rng.integers(0, len(rids)))]
        kv.release(victim)
        after_first = kv.reserved_now
        kv.release(victim)                 # double free
        kv.release(10_000)                 # never admitted
        assert kv.reserved_now == after_first == sum(kv.reserved.values())
        assert kv.reserved_now >= 0

    @given(st.integers(0, 100_000))
    def test_admit_and_grow_refuse_over_budget_atomically(self, seed):
        """A refused admit/grow leaves no partial state behind."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=BUDGET)
        assert kv.admit(0, int(rng.integers(BUDGET // 2, BUDGET + 1)))
        snapshot = (kv.reserved_now, dict(kv.reserved), kv.overflow_events)
        assert not kv.admit(1, BUDGET)     # cannot fit
        assert not kv.grow(0, BUDGET)      # cannot fit either
        assert (kv.reserved_now, dict(kv.reserved),
                kv.overflow_events) == snapshot
        assert 1 not in kv.reserved and 1 not in kv.used

    def test_release_all_returns_pool_to_empty(self):
        kv = KVCacheManager(budget_tokens=BUDGET)
        for rid in range(6):
            kv.admit(rid, 100)
            kv.use(rid, 40)
        kv.tick()
        for rid in range(6):
            kv.release(rid)
        assert kv.reserved_now == 0
        assert kv.reserved == {} and kv.used == {}
        assert kv.total_used_steps <= kv.total_reserved_steps
