"""Property-based invariants for the paged KV reservation allocator.

Random admit/grow/use/shrink (preempt-keep)/reserve (delta resume)/release/
steal op sequences, replayed against
:class:`~repro.serving.kvcache.KVCacheManager`, must never exceed the pool,
never leak or double-assign a page, never corrupt the incremental counters
on double-free, and keep the usage integral below the reservation integral —
the invariants the engine's waste metric and admission control rest on. A
shadow reimplementation of the pre-paged scalar manager pins ``page_size=1``
to the original token-counter semantics bit-exactly. Runs under real
``hypothesis`` when installed, else the seeded example sweep in
``tests/_hypothesis_compat.py``.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.serving.kvcache import KVCacheManager

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")

BUDGET = 1000


def _apply_ops(rng: np.random.Generator, n_ops: int, budget: int = BUDGET):
    """Engine-shaped random op stream: admit / grow / use (within the
    reservation, as the engine guarantees) / release / tick. Yields the
    manager after every op so the caller can assert invariants."""
    kv = KVCacheManager(budget_tokens=budget)
    live = []
    next_rid = 0
    for _ in range(n_ops):
        op = int(rng.integers(0, 5))
        if op == 0:                                   # admit
            need = int(rng.integers(1, budget // 2))
            if kv.admit(next_rid, need):
                live.append(next_rid)
            next_rid += 1
        elif op == 1 and live:                        # grow
            rid = live[int(rng.integers(0, len(live)))]
            kv.grow(rid, int(rng.integers(1, 200)))
        elif op == 2 and live:                        # use within reservation
            rid = live[int(rng.integers(0, len(live)))]
            room = kv.reserved[rid] - kv.used.get(rid, 0)
            if room > 0:
                kv.use(rid, int(rng.integers(1, room + 1)))
        elif op == 3 and live:                        # release (preempt/finish)
            rid = live.pop(int(rng.integers(0, len(live))))
            kv.release(rid)
        else:                                         # tick: integrals advance
            kv.tick()
        yield kv, live


class TestKVCacheProperties:
    @given(st.integers(0, 100_000), st.integers(20, 120))
    def test_pool_never_exceeded_and_counters_consistent(self, seed, n_ops):
        rng = np.random.default_rng(seed)
        for kv, live in _apply_ops(rng, n_ops):
            assert 0 <= kv.reserved_now <= kv.budget_tokens
            assert kv.reserved_now == sum(kv.reserved.values())
            assert set(kv.reserved) == set(live)
            assert kv.peak_reserved <= kv.budget_tokens
            assert kv.reserved_now <= kv.peak_reserved
            for rid, used in kv.used.items():
                assert 0 <= used <= kv.reserved[rid]

    @given(st.integers(0, 100_000), st.integers(20, 120))
    def test_usage_integral_bounded_by_reservation_integral(self, seed, n_ops):
        """total_used_steps <= total_reserved_steps at every point: a token
        can only be used inside a reservation, so the per-tick usage sum can
        never exceed the per-tick reservation sum."""
        rng = np.random.default_rng(seed)
        for kv, _ in _apply_ops(rng, n_ops):
            assert kv.total_used_steps <= kv.total_reserved_steps
            assert 0.0 <= kv.waste_ratio <= 1.0

    @given(st.integers(0, 100_000))
    def test_double_free_is_harmless(self, seed):
        """Releasing a rid twice (or one never admitted) must not corrupt the
        scalar counter or go negative — the engine relies on release being
        idempotent across preempt/evict races."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=BUDGET)
        rids = []
        for rid in range(8):
            if kv.admit(rid, int(rng.integers(1, 200))):
                rids.append(rid)
        before = kv.reserved_now
        assert before == sum(kv.reserved.values())
        victim = rids[int(rng.integers(0, len(rids)))]
        kv.release(victim)
        after_first = kv.reserved_now
        kv.release(victim)                 # double free
        kv.release(10_000)                 # never admitted
        assert kv.reserved_now == after_first == sum(kv.reserved.values())
        assert kv.reserved_now >= 0

    @given(st.integers(0, 100_000))
    def test_admit_and_grow_refuse_over_budget_atomically(self, seed):
        """A refused admit/grow leaves no partial state behind."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=BUDGET)
        assert kv.admit(0, int(rng.integers(BUDGET // 2, BUDGET + 1)))
        snapshot = (kv.reserved_now, dict(kv.reserved), kv.overflow_events)
        assert not kv.admit(1, BUDGET)     # cannot fit
        assert not kv.grow(0, BUDGET)      # cannot fit either
        assert (kv.reserved_now, dict(kv.reserved),
                kv.overflow_events) == snapshot
        assert 1 not in kv.reserved and 1 not in kv.used

    def test_release_all_returns_pool_to_empty(self):
        kv = KVCacheManager(budget_tokens=BUDGET)
        for rid in range(6):
            kv.admit(rid, 100)
            kv.use(rid, 40)
        kv.tick()
        for rid in range(6):
            kv.release(rid)
        assert kv.reserved_now == 0
        assert kv.reserved == {} and kv.used == {}
        assert kv.total_used_steps <= kv.total_reserved_steps


# ---------------------------------------------------------------------------
# paged allocator: page conservation, handoff ops, and scalar equivalence
# ---------------------------------------------------------------------------


class _OldScalarKV:
    """Shadow reimplementation of the pre-paged scalar token counter (the
    seed ``KVCacheManager``), kept verbatim so the ``page_size=1`` manager
    can be pinned to it decision-for-decision and counter-for-counter."""

    def __init__(self, budget_tokens):
        self.budget_tokens = budget_tokens
        self.reserved = {}
        self.used = {}
        self.reserved_now = 0
        self.peak_reserved = 0
        self.overflow_events = 0
        self.total_reserved_steps = 0.0
        self.total_used_steps = 0.0

    def can_admit(self, n):
        return self.reserved_now + n <= self.budget_tokens

    def admit(self, rid, n):
        if not self.can_admit(n):
            return False
        self.reserved[rid] = n
        self.used[rid] = 0
        self.reserved_now += n
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        return True

    def grow(self, rid, extra):
        if self.reserved_now + extra > self.budget_tokens:
            return False
        self.reserved[rid] += extra
        self.reserved_now += extra
        self.overflow_events += 1
        self.peak_reserved = max(self.peak_reserved, self.reserved_now)
        return True

    def use(self, rid, n=1):
        self.used[rid] = self.used.get(rid, 0) + n

    def tick(self):
        self.total_reserved_steps += self.reserved_now
        self.total_used_steps += sum(self.used.values())

    def release(self, rid):
        self.reserved_now -= self.reserved.pop(rid, 0)
        self.used.pop(rid, None)

    @property
    def waste_ratio(self):
        if self.total_reserved_steps == 0:
            return 0.0
        return 1.0 - self.total_used_steps / self.total_reserved_steps


def _apply_paged_ops(rng, n_ops, kv):
    """Engine-shaped op stream over the full paged API — admit, grow, use,
    shrink (keep-mode preempt), reserve (delta resume), release, tick."""
    live, holding = [], []
    next_rid = 0
    for _ in range(n_ops):
        op = int(rng.integers(0, 7))
        if op == 0:                                   # admit
            need = int(rng.integers(1, kv.budget_tokens // 2))
            if kv.admit(next_rid, need):
                live.append(next_rid)
            next_rid += 1
        elif op == 1 and live:                        # grow (overflow)
            rid = live[int(rng.integers(0, len(live)))]
            kv.grow(rid, int(rng.integers(1, 200)))
        elif op == 2 and live:                        # use within reservation
            rid = live[int(rng.integers(0, len(live)))]
            room = kv.reserved[rid] - kv.used.get(rid, 0)
            if room > 0:
                kv.use(rid, int(rng.integers(1, room + 1)))
        elif op == 3 and live:                        # keep-mode preempt
            rid = live.pop(int(rng.integers(0, len(live))))
            kv.shrink(rid, int(rng.integers(0, kv.asked[rid] + 1)))
            holding.append((rid, kv.asked[rid] + int(rng.integers(0, 300))))
        elif op == 4 and holding:                     # delta resume
            rid, need = holding.pop(int(rng.integers(0, len(holding))))
            if kv.reserve(rid, need):
                live.append(rid)
            else:
                holding.append((rid, need))
        elif op == 5 and (live or holding):           # release / timeout
            if live and (not holding or rng.integers(0, 2)):
                rid = live.pop(int(rng.integers(0, len(live))))
            else:
                rid, _ = holding.pop(int(rng.integers(0, len(holding))))
            kv.release(rid)
        else:                                         # tick
            kv.tick()
        yield kv, live, holding


class TestPagedAllocator:
    @given(st.integers(0, 100_000), st.sampled_from([1, 3, 16, 64]))
    def test_no_page_leaked_or_double_assigned(self, seed, page_size):
        """Across admit/grow/preempt-keep/resume/release interleavings the
        explicit page table partitions the pool exactly: every page is in
        the free list or exactly one request's table."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=960, page_size=page_size,
                            track_pages=True)
        for kv, live, holding in _apply_paged_ops(rng, 90, kv):
            owned = [p for tbl in kv.page_table.values() for p in tbl]
            assert len(owned) == len(set(owned))          # no double assign
            assert not set(owned) & set(kv._free_ids)     # no page both ways
            assert len(owned) + len(kv._free_ids) == kv.pages_total  # no leak
            assert kv.pages_free == len(kv._free_ids)
            for rid, granted in kv.reserved.items():
                assert granted == len(kv.page_table.get(rid, [])) * page_size
                # the grant covers the ask and is page-rounded; it may exceed
                # ask + page_size when an overflow grow's one-page minimum
                # lands on top of rounding slack (that slack is exactly what
                # frag_ratio prices — the ask itself never inflates to meet
                # the grant, which was the pre-fix accounting drift)
                assert kv.asked[rid] <= granted
                assert granted % page_size == 0
            assert 0.0 <= kv.fragmentation() <= 1.0

    @given(st.integers(0, 100_000), st.sampled_from([1, 5, 32]))
    def test_incremental_counters_match_dicts(self, seed, page_size):
        """The O(1) counters tick() relies on (used_now/asked_now/
        reserved_now) never drift from a full re-sum of the dicts — the
        hot-loop accounting fix stays exact."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=960, page_size=page_size)
        for kv, live, holding in _apply_paged_ops(rng, 90, kv):
            assert kv.used_now == sum(kv.used.values())
            assert kv.asked_now == sum(kv.asked.values())
            assert kv.reserved_now == sum(kv.reserved.values())
            # usage may legitimately fill page-rounding slack past the ask,
            # so used is only bounded by the granted (reserved) integral
            assert kv.total_used_steps <= kv.total_reserved_steps
            assert kv.total_asked_steps <= kv.total_reserved_steps
            assert 0.0 <= kv.frag_ratio <= 1.0

    @given(st.integers(0, 100_000))
    def test_page_size_one_equals_old_scalar_manager(self, seed):
        """page_size=1 must reproduce the pre-paged scalar token counter
        decision-for-decision, counter-for-counter, on the pre-paged op
        vocabulary (admit/grow/use/release/tick) — including the waste_ratio
        integral on a golden op trace (the tick() regression: incremental
        used_now vs the old per-tick re-sum)."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=BUDGET, page_size=1)
        shadow = _OldScalarKV(budget_tokens=BUDGET)
        live, next_rid = [], 0
        for _ in range(110):
            op = int(rng.integers(0, 5))
            if op == 0:
                need = int(rng.integers(1, BUDGET // 2))
                got = kv.admit(next_rid, need)
                assert shadow.admit(next_rid, need) == got
                if got:
                    live.append(next_rid)
                next_rid += 1
            elif op == 1 and live:
                rid = live[int(rng.integers(0, len(live)))]
                extra = int(rng.integers(1, 200))
                assert shadow.grow(rid, extra) == kv.grow(rid, extra)
            elif op == 2 and live:
                rid = live[int(rng.integers(0, len(live)))]
                room = kv.reserved[rid] - kv.used.get(rid, 0)
                if room > 0:
                    n = int(rng.integers(1, room + 1))
                    kv.use(rid, n)
                    shadow.use(rid, n)
            elif op == 3 and live:
                rid = live.pop(int(rng.integers(0, len(live))))
                kv.release(rid)
                shadow.release(rid)
            else:
                kv.tick()
                shadow.tick()
            assert kv.reserved_now == shadow.reserved_now
            assert kv.reserved == shadow.reserved
            assert kv.used == shadow.used
            assert kv.peak_reserved == shadow.peak_reserved
            assert kv.overflow_events == shadow.overflow_events
            assert kv.total_reserved_steps == shadow.total_reserved_steps
            assert kv.total_used_steps == shadow.total_used_steps
            assert kv.waste_ratio == shadow.waste_ratio
            assert kv.frag_ratio == 0.0       # no page rounding at size 1

    def test_grow_charges_only_the_requested_extra_to_the_ask(self):
        """Regression (pre-fix: ``want = max(asked + extra, reserved + 1)``
        inflated the ask to the grant frontier whenever rounding slack
        absorbed ``extra``, silently understating frag_ratio): a grow's ask
        must rise by exactly ``extra``, even though the grant still adds at
        least one whole page."""
        kv = KVCacheManager(budget_tokens=160, page_size=16)
        assert kv.admit(0, 10)                # asked 10, granted 16
        assert kv.grow(0, 2)                  # slack absorbs the 2 tokens...
        assert kv.asked[0] == 12              # ...pre-fix this said 17
        assert kv.asked_now == 12
        assert kv.reserved[0] == 32           # grant math unchanged: +1 page
        # the page-rounding slack now shows up as fragmentation
        kv.tick()
        assert kv.total_asked_steps == 12.0
        assert kv.total_reserved_steps == 32.0

    @given(st.integers(0, 100_000), st.sampled_from([1, 7, 16, 64]))
    def test_can_reserve_iff_reserve_succeeds(self, seed, page_size):
        """``can_reserve`` and ``reserve`` share one ``want``: across random
        op sequences, for fresh rids, live holders, and shrunk (keep-mode)
        holders alike, the feasibility probe answers exactly whether the
        grant would succeed (probed on a deep copy so the stream is
        undisturbed)."""
        import copy

        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=960, page_size=page_size)
        for kv, live, holding in _apply_paged_ops(rng, 60, kv):
            pool = live + [rid for rid, _ in holding] + [9_999_999]
            rid = pool[int(rng.integers(0, len(pool)))]
            n = int(rng.integers(1, kv.budget_tokens + 200))
            probe = copy.deepcopy(kv)
            assert kv.can_reserve(rid, n) == probe.reserve(rid, n)

    def test_shrink_keeps_filled_pages_and_frees_the_rest(self):
        kv = KVCacheManager(budget_tokens=128, page_size=16, track_pages=True)
        assert kv.admit(0, 100)               # 7 pages = 112 tokens granted
        assert kv.reserved[0] == 112
        kept = kv.shrink(0, 40)               # filled 40 → keep 3 pages
        assert kept == 48 == kv.reserved[0]
        assert kv.pages_free == kv.pages_total - 3
        assert len(kv.page_table[0]) == 3
        # delta resume: back to the full ask reserves only the missing pages
        assert kv.reserve(0, 100)
        assert kv.reserved[0] == 112 and len(kv.page_table[0]) == 7
        kv.release(0)
        assert kv.pages_free == kv.pages_total and kv.page_table == {}

    def test_budget_not_page_aligned_floors_capacity(self):
        kv = KVCacheManager(budget_tokens=100, page_size=16)
        assert kv.pages_total == 6 and kv.capacity_tokens == 96
        assert kv.admit(0, 96)
        assert not kv.can_admit(1)            # the 4 leftover tokens unusable
        kv.release(0)
        assert not kv.admit(1, 97)            # needs 7 pages, pool holds 6


# ---------------------------------------------------------------------------
# posterior re-reservation (reprice): differential shadow model
# ---------------------------------------------------------------------------


class _ShadowPagedKV:
    """Brute-force page-accounting model of the non-sharing paged allocator,
    including the posterior-refinement ``reprice`` primitive — independent
    arithmetic (plain per-rid page counts plus a free counter, re-derived
    sums instead of incremental books) so the real manager's decisions and
    counters can be pinned against it op for op."""

    def __init__(self, budget_tokens, page_size):
        self.page_size = page_size
        self.pages_total = budget_tokens // page_size
        self.free = self.pages_total
        self.granted = {}                     # rid -> pages
        self.asked = {}                       # rid -> tokens

    def _pages(self, n):
        return -(-int(n) // self.page_size)

    @property
    def reserved_now(self):
        return sum(self.granted.values()) * self.page_size

    def admit(self, rid, n):
        k = self._pages(n)
        if k > self.free:
            return False
        self.free -= k
        self.granted[rid] = k
        self.asked[rid] = n
        return True

    def grow(self, rid, extra):
        want = self.asked[rid] + extra
        delta = max(self._pages(want), self.granted[rid] + 1) \
            - self.granted[rid]
        if delta > self.free:
            return False
        self.free -= delta
        self.granted[rid] += delta
        self.asked[rid] = want
        return True

    def shrink(self, rid, keep_tokens):
        keep = min(max(0, int(keep_tokens)),
                   self.granted[rid] * self.page_size)
        k = self._pages(keep)
        self.free += self.granted[rid] - k
        self.granted[rid] = k
        self.asked[rid] = keep
        return k * self.page_size

    def reserve(self, rid, n):
        if rid not in self.granted:
            return self.admit(rid, n)
        want = max(int(n), self.asked[rid])
        delta = self._pages(want) - self.granted[rid]
        if delta > self.free:
            return False
        self.free -= delta
        self.granted[rid] += delta
        self.asked[rid] = want
        return True

    def reprice(self, rid, n):
        if rid not in self.granted:
            return False
        want = max(0, int(n))
        k = self._pages(want)
        if k < self.granted[rid]:
            return self.shrink(rid, want) >= want
        if k > self.granted[rid]:
            if self._pages(max(want, self.asked[rid])) \
                    - self.granted[rid] > self.free:
                return False
            return self.reserve(rid, want)
        return True

    def release(self, rid):
        self.free += self.granted.pop(rid, 0)
        self.asked.pop(rid, None)


def _apply_refine_ops(rng, n_ops, kv, shadow):
    """Random request stream over the refinement op vocabulary — admit /
    grow / shrink (preempt-keep) / reserve (resume) / reprice (posterior
    re-cut, up and down) / release — applied to the real manager and the
    shadow model in lockstep, asserting identical decisions."""
    live, holding = [], []
    next_rid = 0
    for _ in range(n_ops):
        op = int(rng.integers(0, 7))
        if op == 0:                                   # admit
            need = int(rng.integers(1, kv.budget_tokens // 2))
            got = kv.admit(next_rid, need)
            assert shadow.admit(next_rid, need) == got
            if got:
                live.append(next_rid)
            next_rid += 1
        elif op == 1 and live:                        # grow (overflow)
            rid = live[int(rng.integers(0, len(live)))]
            extra = int(rng.integers(1, 200))
            assert shadow.grow(rid, extra) == kv.grow(rid, extra)
        elif op == 2 and live:                        # keep-mode preempt
            rid = live.pop(int(rng.integers(0, len(live))))
            keep = int(rng.integers(0, kv.asked[rid] + 1))
            assert shadow.shrink(rid, keep) == kv.shrink(rid, keep)
            holding.append(rid)
        elif op == 3 and holding:                     # delta resume
            rid = holding.pop(int(rng.integers(0, len(holding))))
            need = kv.asked[rid] + int(rng.integers(0, 300))
            got = kv.reserve(rid, need)
            assert shadow.reserve(rid, need) == got
            (live if got else holding).append(rid)
        elif op == 4 and live:                        # posterior re-cut
            rid = live[int(rng.integers(0, len(live)))]
            want = int(rng.integers(1, kv.budget_tokens + 100))
            assert shadow.reprice(rid, want) == kv.reprice(rid, want)
        elif op == 5 and (live or holding):           # release / timeout
            pool = live if live and (not holding or rng.integers(0, 2)) \
                else holding
            rid = pool.pop(int(rng.integers(0, len(pool))))
            kv.release(rid)
            shadow.release(rid)
        else:
            kv.tick()
        yield kv, shadow, live, holding


class TestRepriceDifferential:
    @given(st.integers(0, 100_000), st.sampled_from([1, 7, 16, 64]))
    def test_reprice_matches_shadow_and_strands_no_pages(self, seed,
                                                         page_size):
        """Decision-for-decision, book-for-book equivalence with the
        brute-force model across random refine streams; afterwards a full
        release drain returns every page — shrink-on-refine never strands
        pages and the ``reserved_now``/``logical_now`` books balance."""
        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=960, page_size=page_size,
                            track_pages=True)
        shadow = _ShadowPagedKV(960, page_size)
        for kv, shadow, live, holding in _apply_refine_ops(rng, 90, kv,
                                                           shadow):
            assert kv.reserved_now == shadow.reserved_now
            assert kv.logical_now == kv.reserved_now   # no sharing
            assert kv.pages_free == shadow.free
            assert kv.asked == shadow.asked
            for rid, k in shadow.granted.items():
                assert kv.reserved[rid] == k * page_size
            owned = [p for tbl in kv.page_table.values() for p in tbl]
            assert len(owned) == len(set(owned))
            assert len(owned) + len(kv._free_ids) == kv.pages_total
        for rid in list(kv.reserved):
            kv.release(rid)
        assert kv.reserved_now == 0 and kv.logical_now == 0
        assert kv.pages_free == kv.pages_total
        assert kv.page_table == {}

    @given(st.integers(0, 100_000), st.sampled_from([1, 7, 16, 64]))
    def test_reprice_grow_iff_can_reserve(self, seed, page_size):
        """Grow-on-refine respects admission feasibility exactly: whenever
        the posterior target needs new pages, ``reprice`` succeeds iff
        ``can_reserve`` says the delta fits, and a refused grow leaves the
        reservation untouched."""
        import copy

        rng = np.random.default_rng(seed)
        kv = KVCacheManager(budget_tokens=960, page_size=page_size)
        shadow = _ShadowPagedKV(960, page_size)
        for kv, shadow, live, holding in _apply_refine_ops(rng, 60, kv,
                                                           shadow):
            if not live:
                continue
            rid = live[int(rng.integers(0, len(live)))]
            want = int(rng.integers(1, kv.budget_tokens + 200))
            if kv.pages_for(want) <= kv.pages_of(rid):
                continue                      # shrink/no-op side: always ok
            feasible = kv.can_reserve(rid, want)
            snapshot = (kv.reserved_now, dict(kv.reserved), dict(kv.asked),
                        kv.overflow_events)
            got = kv.reprice(rid, want)
            assert got == feasible
            assert shadow.reprice(rid, want) == got
            if got:
                assert kv.reserved[rid] >= want
                assert kv.overflow_events == snapshot[3]  # not an overflow
            else:
                assert (kv.reserved_now, dict(kv.reserved), dict(kv.asked),
                        kv.overflow_events) == snapshot

    def test_reprice_never_releases_shared_prefix_pages(self):
        """With prefix sharing on, a posterior shrink below the shared-token
        floor keeps the prefix-backed pages (they belong to the prefix
        store) and the physical/logical books stay split correctly."""
        kv = KVCacheManager(budget_tokens=512, page_size=16,
                            share_prefixes=True)
        assert kv.admit(0, 128, prefix_id="s", prefix_len=64)
        assert kv.admit(1, 128, prefix_id="s", prefix_len=64)
        shared = kv.shared_tokens_of(0)
        assert shared == 64
        logical_before = kv.logical_now
        assert kv.reprice(0, 8)               # far below the shared floor
        assert kv.reserved[0] >= shared
        assert kv.logical_now < logical_before
        assert kv.reserved_now <= kv.capacity_tokens
        kv.release(0)
        kv.release(1)
        assert kv.reserved_now == 0 and kv.logical_now == 0

    def test_reprice_unknown_rid_is_refused(self):
        kv = KVCacheManager(budget_tokens=256, page_size=16)
        assert not kv.reprice(42, 64)
        assert kv.reserved_now == 0 and kv.pages_free == kv.pages_total
