"""Track-B end-to-end: train the tiny LM on the heavy-tailed toy corpus,
generate repeated samples at temperature 0.8, and verify the full ProD
pipeline (real hidden states -> targets -> head -> predictions)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PredictorConfig, TrainConfig
from repro.configs import get_config
from repro.core import bins as B
from repro.core import targets as T
from repro.core.metrics import mae, noise_radius
from repro.core.predictor import train_predictor
from repro.data.pipeline import batch_iterator, make_lm_dataset
from repro.data.tokenizer import N_TOPICS, ToyTokenizer
from repro.models.model_zoo import Runtime, build_model
from repro.serving.engine import RealEngine
from repro.training.trainer import train_loop


@pytest.fixture(scope="module")
def tiny_trained():
    cfg = get_config("tiny-lm").with_overrides(dtype="float32", n_layers=2,
                                               d_model=96, n_heads=4,
                                               n_kv_heads=2, d_ff=256)
    model = build_model(cfg)
    tcfg = TrainConfig(lr=4e-3, warmup_steps=5, decay_steps=120, seed=0)
    ds = make_lm_dataset(512, 64, seed=0)
    it = batch_iterator(ds, 16, seed=0)
    state = train_loop(model, tcfg, it, 120, rt=Runtime.local(), verbose=False)
    return model, state.params


@pytest.mark.slow
def test_real_generation_prod_pipeline(tiny_trained):
    model, params = tiny_trained
    eng = RealEngine(model, params, max_new=80, temperature=0.8)
    rng = np.random.default_rng(0)
    tok = ToyTokenizer()
    n, r = 48, 6
    prompts = np.zeros((n, 6), np.int32)
    topics = rng.integers(0, N_TOPICS, n)
    for i in range(n):
        prompts[i] = tok.prompt(rng, int(topics[i]), n_style=4)
    plens = np.full(n, 6)
    lens, phi = eng.repeated_sampling(prompts, plens, r=r, seed=0)

    # Observation 1: repeated generations of the same prompt differ
    spread = np.mean(np.abs(lens - np.median(lens, axis=1, keepdims=True)))
    assert spread > 0.5, "temperature-0.8 decoding should be stochastic"
    assert phi.shape == (n, model.cfg.d_model)
    assert np.isfinite(phi).all()

    # full ProD-D pipeline on real hidden states
    pcfg = PredictorConfig(n_bins=16, bin_max=float(lens.max() + 4), epochs=20,
                           batch_size=32)
    edges = B.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = T.dist_target(jnp.asarray(lens, jnp.float32), edges)
    pred = train_predictor(jax.random.PRNGKey(0), jnp.asarray(phi), tgt, pcfg,
                           edges)
    est = pred.predict(jnp.asarray(phi))
    assert est.shape == (n,)
    assert bool(jnp.all(jnp.isfinite(est)))
    med = np.median(lens, axis=1)
    m = mae(est, jnp.asarray(med))
    const = float(np.mean(np.abs(med - np.median(med))))
    assert m <= const + 2.0, (m, const)  # at least on par with constant
