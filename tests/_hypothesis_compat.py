"""Optional-import shim for ``hypothesis``.

When hypothesis is installed, re-export the real ``given``/``settings``/
``strategies``. When it is absent (the CPU CI image does not ship it), fall
back to a seeded-random example sweep: ``@given`` draws ``max_examples``
pseudo-random examples from lightweight strategy stand-ins, with the seed
derived from the test name so every run replays the same examples. Property
tests then still collect and exercise a meaningful input sweep either way.

Usage in test modules::

    from _hypothesis_compat import given, settings, strategies as st
"""

from __future__ import annotations

import zlib

try:  # pragma: no cover - depends on the environment
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function over a seeded numpy Generator."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))
            ])

    class settings:  # noqa: N801 - mimics the hypothesis class name
        _profiles: dict = {}
        _active: dict = {}

        def __init__(self, parent=None, **kwargs):
            self.kwargs = kwargs

        def __call__(self, fn):
            fn._compat_settings = {**type(self)._active, **self.kwargs}
            return fn

        @classmethod
        def register_profile(cls, name, parent=None, **kwargs):
            cls._profiles[name] = kwargs

        @classmethod
        def load_profile(cls, name):
            cls._active = dict(cls._profiles.get(name, {}))

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            def wrapper(*args, **kwargs):
                conf = {**settings._active,
                        **getattr(wrapper, "_compat_settings", {})}
                n = conf.get("max_examples") or 20
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = [s.draw(rng) for s in arg_strategies]
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # NOT functools.wraps: copying __wrapped__ would expose the
            # original signature and make pytest treat the drawn arguments
            # as fixtures. The wrapper must look 0-ary (plus self).
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return decorate
