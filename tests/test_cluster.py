"""Cluster simulator: arrival traces, router/engine invariants, and the
vectorized-vs-reference SimEngine regression — including the heterogeneous
(ReplicaSpec), prefill-cost, SLO/timeout, and work-stealing code paths."""

import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.data.lengths import LengthLaw, law_quantile, sample_lengths
from repro.serving.arrivals import (LatentOracle, TraceConfig, arrival_times,
                                    make_trace, stable_rate_specs)
from repro.serving.cluster import Cluster, ROUTERS, STEAL_MODES
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.request import Request
from repro.serving.scheduler import Policy

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def _trace(n=300, pattern="poisson", rate=1.0, seed=0, **kw):
    kw.setdefault("max_seq_len", 512)
    kw.setdefault("model", "llama")
    kw.setdefault("scenario", "math")
    return make_trace(TraceConfig(n_requests=n, pattern=pattern, rate=rate,
                                  seed=seed, **kw))


QPOL = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)


class TestArrivals:
    def test_trace_deterministic(self):
        a = _trace(200, seed=5)
        b = _trace(200, seed=5)
        assert [(r.rid, r.arrival, r.prompt_len, r.true_len) for r in a] == \
               [(r.rid, r.arrival, r.prompt_len, r.true_len) for r in b]
        np.testing.assert_array_equal(np.stack([r.phi for r in a]),
                                      np.stack([r.phi for r in b]))

    def test_mix_covers_all_eight_settings(self):
        reqs = _trace(2000, model="mix", scenario="mix")
        assert len({r.setting for r in reqs}) == 8

    def test_lengths_heavy_tailed(self):
        reqs = _trace(2000, model="qwen", scenario="chat", max_seq_len=1 << 16)
        L = np.array([r.true_len for r in reqs])
        assert L.max() / np.median(L) > 4.0  # paper: multi-x tail draws

    def test_bursty_more_variable_than_poisson(self):
        cfg_p = TraceConfig(n_requests=4000, pattern="poisson", rate=1.0)
        cfg_b = TraceConfig(n_requests=4000, pattern="bursty", rate=1.0)
        rng = np.random.default_rng(0)
        gaps_p = np.diff(arrival_times(cfg_p, rng))
        gaps_b = np.diff(arrival_times(cfg_b, np.random.default_rng(0)))
        cv = lambda x: x.std() / x.mean()
        assert cv(gaps_b) > 1.5 * cv(gaps_p)

    def test_diurnal_modulates_rate(self):
        cfg = TraceConfig(n_requests=6000, pattern="diurnal", rate=1.0,
                          diurnal_period=4000.0, diurnal_amp=0.8)
        ts = arrival_times(cfg, np.random.default_rng(0))
        phase = np.mod(ts, cfg.diurnal_period) / cfg.diurnal_period
        peak = np.sum((phase > 0.05) & (phase < 0.45))    # sin > 0 half
        trough = np.sum((phase > 0.55) & (phase < 0.95))  # sin < 0 half
        assert peak > 1.5 * trough

    def test_mean_rate_preserved_by_patterns(self):
        for pattern in ("poisson", "bursty", "diurnal"):
            # short diurnal period so the trace spans many full cycles (the
            # rate is only mean-preserving over whole periods)
            cfg = TraceConfig(n_requests=20_000, pattern=pattern, rate=2.0,
                              diurnal_period=500.0)
            ts = arrival_times(cfg, np.random.default_rng(1))
            rate = len(ts) / ts[-1]
            assert rate == pytest.approx(2.0, rel=0.25), pattern

    def test_prompt_max_is_reachable(self):
        """Regression: the prompt sampler excluded its own upper bound
        (``rng.integers`` is right-open without ``endpoint=True``), so the
        configured prompt_max never appeared in any trace."""
        reqs = _trace(3000, prompt_min=16, prompt_max=32)
        lens = {r.prompt_len for r in reqs}
        assert max(lens) == 32          # 3000 draws over 17 values: certain
        assert min(lens) >= 16

    def test_negative_diurnal_amp_rejected(self):
        with pytest.raises(ValueError):
            TraceConfig(n_requests=10, rate=1.0, diurnal_amp=-0.2)

    def test_diurnal_amp_over_one_keeps_mean_rate(self):
        """Regression: amp > 1 clips the sinusoid at 0 but the old lambda
        normalization ignored the clipping, inflating the realized rate
        ~9% at amp=1.5. The renormalized intensity must hold the configured
        mean over whole periods (and produce dead troughs)."""
        cfg = TraceConfig(n_requests=20_000, pattern="diurnal", rate=2.0,
                          diurnal_period=500.0, diurnal_amp=1.5)
        ts = arrival_times(cfg, np.random.default_rng(1))
        assert len(ts) / ts[-1] == pytest.approx(2.0, rel=0.04)
        # the clipped trough really is silent: the sin<0 quarter around the
        # minimum (phase 0.75) has lambda == 0 for amp > 1
        phase = np.mod(ts, cfg.diurnal_period) / cfg.diurnal_period
        dead = np.sum((phase > 0.70) & (phase < 0.80))
        assert dead == 0


class TestLatentOracle:
    def test_quantiles_monotone_and_above_median(self):
        reqs = _trace(500, model="qwen", scenario="longseq")
        phi = np.stack([r.phi for r in reqs])
        o = LatentOracle()
        q50, q90, q99 = (o.quantile(phi, q) for q in (0.5, 0.9, 0.99))
        assert np.all(q50 <= q90 + 1e-6) and np.all(q90 <= q99 + 1e-6)
        med = o.predict(phi)
        assert np.mean(q90 > med) > 0.95  # body+tail q90 sits above median

    def test_law_quantile_matches_empirical(self):
        law = LengthLaw(median_scale=200, median_spread=0.5, sigma_body=0.15,
                        tail_weight=0.05, tail_alpha=2.5)
        lat = np.array([[np.log(200.0), 0.15, 0.05, 2.5]])
        rng = np.random.default_rng(0)
        draws = sample_lengths(rng, lat, 200_000, law)[0]
        for q in (0.5, 0.9, 0.99):
            got = float(law_quantile(lat, q)[0])
            want = float(np.quantile(draws, q))
            assert got == pytest.approx(want, rel=0.05), q


def _row_and_finishes(engine_or_cluster, reqs):
    stv = engine_or_cluster.run(reqs)
    if hasattr(engine_or_cluster, "engines"):
        done = [r for e in engine_or_cluster.engines for r in e.done]
    else:
        done = engine_or_cluster.done
    return stv.row(), sorted((r.rid, r.t_start, r.t_finish) for r in done)


class TestVectorizedRegression:
    @pytest.mark.parametrize("pol", [
        Policy("fcfs", "max", max_seq_len=512),
        Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512),
        Policy("sjf_pred", "predicted", margin=1.1, max_seq_len=512),
        Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
               preempt=True),
    ])
    def test_engine_vec_matches_ref(self, pol):
        """The NumPy fast path (incl. event leap) must reproduce the per-slot
        reference decode bit-for-bit: same stats, same per-request timings."""
        reqs = _trace(150, pattern="bursty", rate=0.8, seed=7)
        oracle = LatentOracle()
        kv = 3 * (256 + 512)
        ra, fa = _row_and_finishes(
            SimEngine(6, kv, pol, predictor=oracle, vectorized=True), reqs)
        rb, fb = _row_and_finishes(
            SimEngine(6, kv, pol, predictor=oracle, vectorized=False), reqs)
        assert ra == rb
        assert fa == fb

    @pytest.mark.parametrize("router", ROUTERS)
    def test_cluster_vec_matches_ref(self, router):
        reqs = _trace(200, pattern="bursty", rate=1.2, seed=11)
        oracle = LatentOracle()
        ra, fa = _row_and_finishes(
            Cluster.uniform(3, 4, 2 * (256 + 512), QPOL, router=router,
                            predictor=oracle, vectorized=True), reqs)
        rb, fb = _row_and_finishes(
            Cluster.uniform(3, 4, 2 * (256 + 512), QPOL, router=router,
                            predictor=oracle, vectorized=False), reqs)
        assert ra == rb
        assert fa == fb

    @given(st.integers(0, 10_000))
    def test_engine_vec_matches_ref_random(self, seed):
        reqs = _trace(60, pattern="poisson", rate=0.6, seed=seed)
        pol = Policy("fcfs", "quantile", quantile=0.85, max_seq_len=512)
        kv = 2 * (256 + 512)
        ra, fa = _row_and_finishes(
            SimEngine(4, kv, pol, predictor=LatentOracle(),
                      vectorized=True), reqs)
        rb, fb = _row_and_finishes(
            SimEngine(4, kv, pol, predictor=LatentOracle(),
                      vectorized=False), reqs)
        assert ra == rb and fa == fb


class TestClusterInvariants:
    def _run(self, router="psq", n=600, seed=0):
        reqs = _trace(n, pattern="bursty", rate=1.5, seed=seed)
        cl = Cluster.uniform(4, 4, 2 * (256 + 512), QPOL, router=router,
                             predictor=LatentOracle())
        stats = cl.run(reqs)
        return cl, stats, reqs

    def test_every_request_completes_exactly_once(self):
        cl, stats, reqs = self._run()
        done = [r for e in cl.engines for r in e.done]
        assert stats.completed == len(reqs) == len(done)
        assert {r.rid for r in done} == {r.rid for r in reqs}

    def test_each_request_assigned_one_replica(self):
        cl, _, reqs = self._run(router="least_kv")
        for e_idx, e in enumerate(cl.engines):
            assert all(r.replica == e_idx for r in e.done)

    def test_kv_pages_conserved_per_replica(self):
        cl, _, _ = self._run()
        for e in cl.engines:
            assert e.kv.reserved_now == 0          # all reservations released
            assert e.kv.reserved == {}             # scalar/dict in sync
            assert e.kv.peak_reserved <= e.kv.budget_tokens
            assert 0.0 <= e.kv.waste_ratio <= 1.0

    def test_deterministic_replay(self):
        _, sa, _ = self._run(seed=3)
        _, sb, _ = self._run(seed=3)
        assert sa.row() == sb.row()

    def test_round_robin_spreads_requests(self):
        cl, _, reqs = self._run(router="round_robin")
        counts = [len(e.done) for e in cl.engines]
        assert max(counts) - min(counts) <= 1


class TestEngineStepInvariants:
    def test_no_slot_double_occupancy_and_budget(self):
        """Drive the stepwise API directly, asserting per-tick invariants:
        distinct rids in slots, slot cap, budget never exceeded, scalar
        reservation counter consistent with the per-request dict."""
        reqs = _trace(120, rate=2.0, seed=13)
        for r in reqs:
            r.reserve_len = 300.0   # pre-annotated quantile-ish reservations
        pol = Policy("fcfs", "quantile", max_seq_len=512)
        eng = SimEngine(max_slots=3, kv_budget=2500, policy=pol)
        from repro.serving.scheduler import annotate_predictions
        annotate_predictions(reqs, None, pol)
        eng.submit(reqs)
        guard = 0
        while not eng.idle and guard < 200_000:
            eng.step()
            guard += 1
            rids = [r.rid for r in eng._slots]
            assert len(rids) == len(set(rids)) == eng._n_active
            assert eng._n_active <= eng.max_slots
            assert eng.kv.reserved_now <= eng.kv.budget_tokens
            assert eng.kv.reserved_now == sum(eng.kv.reserved.values())
        assert eng.idle
        assert len(eng.done) == len(reqs)


class TestDeadlockRecovery:
    def test_kv_exhaustion_does_not_livelock(self):
        """All slots stalled on grows the budget can't satisfy must trigger
        OOM eviction (progress-keeping preemption), not an infinite stall:
        every request still completes, in both decode paths, identically."""
        reqs = _trace(250, pattern="bursty", rate=1.2, seed=3,
                      model="mix", scenario="mix")
        pol = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
                     preempt=True)
        rows = {}
        for vec in (True, False):
            eng = SimEngine(4, 2 * (256 + 512), pol, predictor=LatentOracle(),
                            vectorized=vec)
            stats = eng.run(reqs, max_steps=500_000)
            assert stats.completed == len(reqs)
            assert stats.oom_evictions > 0       # the deadlock was hit+broken
            assert eng.kv.reserved_now == 0
            rows[vec] = stats.row()
        assert rows[True] == rows[False]

    def test_unservable_request_is_dropped_not_livelocked(self):
        """A request needing more KV than the entire pool can never finish;
        it must be dropped (after its reservation ask hits the pool cap)
        instead of cycling evict/admit until max_steps."""
        from repro.serving.request import Request
        big = Request(rid=0, arrival=0.0, prompt_len=256, true_len=2000,
                      reserve_len=300.0, predicted_len=300.0)
        ok = Request(rid=1, arrival=1.0, prompt_len=32, true_len=100,
                     reserve_len=150.0, predicted_len=100.0)
        pol = Policy("fcfs", "quantile", max_seq_len=4096)
        st = SimEngine(2, 1024, pol).run([big, ok], max_steps=100_000)
        assert st.dropped == 1
        assert st.completed == 1          # the servable request still finishes
        assert st.makespan < 10_000       # terminated, not max_steps spin

    def test_eviction_ask_never_exceeds_pool(self):
        """Escalating reservation asks are clamped to the pool size, so an
        evicted request always stays admittable."""
        reqs = _trace(300, pattern="bursty", rate=2.0, seed=9,
                      model="mix", scenario="mix")
        pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)
        eng = SimEngine(6, 1536, pol, predictor=LatentOracle())
        st = eng.run(reqs, max_steps=500_000)
        assert st.completed + st.dropped == len(reqs)
        assert st.completed == len(reqs)  # this trace fits the pool
        assert eng.kv.reserved_now == 0

    def test_empty_run_returns_empty_stats(self):
        pol = Policy("fcfs", "quantile", max_seq_len=512)
        st = SimEngine(4, 1000, pol, predictor=LatentOracle()).run([])
        assert st.completed == 0
        cst = Cluster.uniform(2, 4, 1000, pol, router="psq",
                              predictor=LatentOracle()).run([])
        assert cst.completed == 0


class TestRouterQuality:
    def test_quantile_reservation_beats_max_reserve(self):
        """Tight KV budget: distributional reservation admits far more
        concurrency than max-reserve, cutting p99 latency AND waste."""
        reqs = _trace(800, pattern="bursty", rate=1.2, seed=2,
                      model="mix", scenario="mix")
        naive = Cluster.uniform(4, 8, 2 * (256 + 512),
                                Policy("fcfs", "max", max_seq_len=512),
                                router="round_robin",
                                predictor=LatentOracle()).run(reqs)
        prod = Cluster.uniform(4, 8, 2 * (256 + 512), QPOL, router="psq",
                               predictor=LatentOracle()).run(reqs)
        assert prod.completed == naive.completed == len(reqs)
        assert prod.p99_latency < naive.p99_latency
        assert prod.kv_waste_ratio < naive.kv_waste_ratio


# ---------------------------------------------------------------------------
# heterogeneous replicas, prefill cost, SLOs, and work stealing
# ---------------------------------------------------------------------------

HET_SPECS = (
    ReplicaSpec(4, 2 * (256 + 512), speed=2, prefill_tokens_per_step=64),
    ReplicaSpec(2, 256 + 512, speed=1, prefill_tokens_per_step=32),
    ReplicaSpec(6, 3 * (256 + 512), speed=3),
)


def _feature_cluster(feat, vectorized, router="psq"):
    kw = {}
    if feat in ("steal", "steal_quantile", "all"):
        kw = dict(rebalance_every=25,
                  steal="quantile" if feat != "steal" else "tail")
    specs = (HET_SPECS if feat in ("hetero", "all")
             else (ReplicaSpec(4, 2 * (256 + 512),
                               prefill_tokens_per_step=64),) * 3
             if feat == "prefill" else (ReplicaSpec(4, 2 * (256 + 512)),) * 3)
    return Cluster(specs, QPOL, router=router, predictor=LatentOracle(),
                   vectorized=vectorized, **kw)


class TestNewFeatureVecRegression:
    """The event-leap fast path must stay bit-identical to the per-slot
    reference on every new axis: prefill cost, heterogeneous specs,
    deadlines/timeouts, and work stealing — separately and combined."""

    @pytest.mark.parametrize("feat", ["prefill", "hetero", "steal",
                                      "steal_quantile", "all"])
    def test_cluster_vec_matches_ref_features(self, feat):
        slo = dict(slo_factor=3.0, slo_floor=50.0) if feat in ("slo", "all") \
            else {}
        reqs = _trace(250, pattern="bursty", rate=1.5, seed=11, **slo)
        ra, fa = _row_and_finishes(_feature_cluster(feat, True), reqs)
        rb, fb = _row_and_finishes(_feature_cluster(feat, False), reqs)
        assert ra == rb
        assert fa == fb

    @pytest.mark.parametrize("router", ROUTERS)
    def test_cluster_vec_matches_ref_slo(self, router):
        reqs = _trace(250, pattern="bursty", rate=2.0, seed=5,
                      slo_factor=3.0, slo_floor=50.0)
        ra, fa = _row_and_finishes(_feature_cluster("slo", True, router), reqs)
        rb, fb = _row_and_finishes(_feature_cluster("slo", False, router), reqs)
        assert ra == rb and fa == fb
        assert ra["timed_out"] > 0      # the SLO path was actually exercised

    @pytest.mark.parametrize("spec", [
        ReplicaSpec(6, 3 * (256 + 512), speed=3, prefill_tokens_per_step=48),
        ReplicaSpec(6, 3 * (256 + 512), speed=1, prefill_tokens_per_step=16),
        ReplicaSpec(6, 3 * (256 + 512), speed=4),
    ])
    def test_engine_vec_matches_ref_speed_prefill(self, spec):
        reqs = _trace(200, pattern="bursty", rate=1.2, seed=7,
                      slo_factor=4.0, slo_floor=100.0)
        pol = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
                     preempt=True)
        ra, fa = _row_and_finishes(
            SimEngine(policy=pol, predictor=LatentOracle(), vectorized=True,
                      spec=spec), reqs)
        rb, fb = _row_and_finishes(
            SimEngine(policy=pol, predictor=LatentOracle(), vectorized=False,
                      spec=spec), reqs)
        assert ra == rb and fa == fb

    @given(st.integers(0, 10_000))
    def test_engine_vec_matches_ref_random_features(self, seed):
        rng = np.random.default_rng(seed)
        spec = ReplicaSpec(int(rng.integers(2, 7)), 2 * (256 + 512),
                           speed=int(rng.integers(1, 5)),
                           prefill_tokens_per_step=int(rng.integers(0, 5))
                           * 32)
        reqs = _trace(60, pattern="poisson", rate=0.6, seed=seed,
                      slo_factor=5.0, slo_floor=64.0)
        pol = Policy("fcfs", "quantile", quantile=0.85, max_seq_len=512)
        ra, fa = _row_and_finishes(
            SimEngine(policy=pol, predictor=LatentOracle(), vectorized=True,
                      spec=spec), reqs)
        rb, fb = _row_and_finishes(
            SimEngine(policy=pol, predictor=LatentOracle(), vectorized=False,
                      spec=spec), reqs)
        assert ra == rb and fa == fb

    def test_golden_cluster_stats_deterministic(self):
        """Same seed ⇒ the exact same ClusterStats row dict, twice over, on
        the all-features configuration (hetero + SLO + stealing)."""
        reqs = _trace(300, pattern="bursty", rate=1.5, seed=21,
                      slo_factor=3.0, slo_floor=50.0)
        rows = [Cluster(HET_SPECS, QPOL, router="psq",
                        predictor=LatentOracle(), rebalance_every=40,
                        steal="quantile").run(reqs).row() for _ in range(2)]
        assert rows[0] == rows[1]
        # and the run exercised every new subsystem
        assert rows[0]["stolen"] > 0
        assert rows[0]["timed_out"] > 0
        assert rows[0]["completed"] + rows[0]["timed_out"] \
            + rows[0]["dropped"] == len(reqs)


class TestSLOAccounting:
    def test_timeouts_and_violations_partition(self):
        """Every request is exactly one of: completed in SLO, completed late
        (slo_violation), timed out in queue, or dropped as unservable."""
        reqs = _trace(500, pattern="bursty", rate=2.5, seed=4,
                      slo_factor=2.0, slo_floor=30.0)
        cl = Cluster(HET_SPECS, QPOL, router="psq", predictor=LatentOracle())
        stats = cl.run(reqs)
        done = [r for e in cl.engines for r in e.done]
        timed = [r for e in cl.engines for r in e.timed_out_requests]
        assert stats.completed == len(done)
        assert stats.timed_out == len(timed) > 0
        assert len(done) + len(timed) + stats.dropped == len(reqs)
        late = sum(1 for r in done if not r.slo_met)
        assert stats.slo_violations == late
        for r in timed:
            assert r.t_finish is None and r.deadline < stats.makespan
        # goodput counts only within-SLO tokens, so it is below throughput
        assert 0.0 < stats.goodput <= stats.throughput

    def test_no_slo_means_no_timeouts(self):
        reqs = _trace(300, pattern="bursty", rate=1.5, seed=4)
        stats = Cluster(HET_SPECS, QPOL, router="psq",
                        predictor=LatentOracle()).run(reqs)
        assert stats.timed_out == 0 and stats.slo_violations == 0
        assert stats.completed == len(reqs)
        assert stats.goodput == pytest.approx(stats.throughput)

    def test_trace_deadlines_per_class(self):
        """Mixed traces give each model×scenario class its own SLO budget
        (proportional to the class's typical length)."""
        reqs = _trace(2000, model="mix", scenario="mix", slo_factor=2.0,
                      slo_floor=10.0)
        budgets = {}
        for r in reqs:
            budgets.setdefault(r.setting, set()).add(
                round(r.deadline - r.arrival, 6))
        assert len(budgets) == 8
        for setting, b in budgets.items():
            assert len(b) == 1, setting    # one budget per class
        assert len({next(iter(b)) for b in budgets.values()}) > 1


class TestPrefillCost:
    def test_prefill_delays_first_token(self):
        """With prefill cost, a request's finish is pushed back by exactly
        ceil(prompt_len / rate) ticks relative to the free-prefill engine
        (single request: no queueing interactions)."""
        pol = Policy("fcfs", "quantile", max_seq_len=512)
        r = Request(rid=0, arrival=0.0, prompt_len=100, true_len=50,
                    reserve_len=64.0, predicted_len=50.0)
        free = SimEngine(policy=pol, spec=ReplicaSpec(2, 4096)).run([r])
        paid = SimEngine(policy=pol, spec=ReplicaSpec(
            2, 4096, prefill_tokens_per_step=16)).run([r])
        assert paid.mean_latency == free.mean_latency + int(np.ceil(100 / 16))

    def test_speed_shrinks_makespan(self):
        reqs = _trace(300, rate=1.0, seed=6)
        pol = Policy("fcfs", "quantile", max_seq_len=512)
        kv = 4 * (256 + 512)
        slow = SimEngine(policy=pol, predictor=LatentOracle(),
                         spec=ReplicaSpec(4, kv, speed=1)).run(reqs)
        fast = SimEngine(policy=pol, predictor=LatentOracle(),
                         spec=ReplicaSpec(4, kv, speed=4)).run(reqs)
        assert fast.completed == slow.completed == len(reqs)
        assert fast.makespan < slow.makespan
        assert fast.mean_latency < slow.mean_latency

    def test_replica_spec_validation(self):
        with pytest.raises(ValueError):
            ReplicaSpec(0, 100)
        with pytest.raises(ValueError):
            ReplicaSpec(2, 100, speed=0)
        with pytest.raises(ValueError):
            ReplicaSpec(2, 100, prefill_tokens_per_step=-1)


class TestWorkStealing:
    def _overloaded_cluster(self, steal=None, rebalance_every=0,
                            router="round_robin"):
        # slow small replica next to a fast big one: round_robin overloads
        # the slow one, so there is real imbalance to steal away
        specs = (ReplicaSpec(2, 256 + 512, speed=1),
                 ReplicaSpec(8, 4 * (256 + 512), speed=3))
        return Cluster(specs, QPOL, router=router, predictor=LatentOracle(),
                       rebalance_every=rebalance_every,
                       steal=steal or "tail")

    def test_stealing_moves_queued_requests(self):
        reqs = _trace(400, pattern="bursty", rate=2.0, seed=8)
        st_off = self._overloaded_cluster().run(reqs)
        st_on = self._overloaded_cluster(rebalance_every=20).run(reqs)
        assert st_off.stolen == 0
        assert st_on.stolen > 0
        assert st_on.completed == st_off.completed == len(reqs)
        assert st_on.p99_latency < st_off.p99_latency

    @pytest.mark.parametrize("mode", STEAL_MODES)
    def test_steal_preserves_requests(self, mode):
        """No request is lost or duplicated by migration, and stolen ones
        finish on their new replica."""
        reqs = _trace(400, pattern="bursty", rate=2.0, seed=9)
        cl = self._overloaded_cluster(steal=mode, rebalance_every=20)
        stats = cl.run(reqs)
        assert stats.stolen > 0
        done = [r for e in cl.engines for r in e.done]
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
        for e_idx, e in enumerate(cl.engines):
            assert all(r.replica == e_idx for r in e.done)

    def test_quantile_steal_moves_bigger_work(self):
        """The ProD-aware selector migrates requests with larger predicted
        quantile remaining work than the tail selector does."""
        reqs = _trace(600, pattern="bursty", rate=2.5, seed=10)

        def mean_stolen_reserve(mode):
            specs = (ReplicaSpec(2, 256 + 512), ReplicaSpec(8, 4 * (256 + 512)))
            cl = Cluster(specs, QPOL, router="jsq", predictor=LatentOracle(),
                         rebalance_every=20, steal=mode)
            moved = []
            orig = SimEngine.steal_queued

            def spy(self, k, mode="tail", fit=None, fit_page_size=1):
                out = orig(self, k, mode, fit, fit_page_size)
                moved.extend(float(r.reserve_len) for r in out)
                return out

            SimEngine.steal_queued = spy
            try:
                cl.run(reqs)
            finally:
                SimEngine.steal_queued = orig
            return float(np.mean(moved)) if moved else 0.0

        tail, quant = mean_stolen_reserve("tail"), mean_stolen_reserve("quantile")
        assert quant > 0 and tail > 0
        assert quant >= tail

    def test_stealing_helps_hetero_slo(self):
        """Acceptance: on a heterogeneous 4-replica fleet under SLO pressure,
        psq+quantile with stealing beats round_robin on p99 latency AND SLO
        violations."""
        specs = (ReplicaSpec(8, 4 * (256 + 512), speed=2),
                 ReplicaSpec(8, 4 * (256 + 512), speed=2),
                 ReplicaSpec(4, 2 * (256 + 512), speed=1),
                 ReplicaSpec(4, 2 * (256 + 512), speed=1))
        probe = _trace(500, seed=12)
        rate = stable_rate_specs(
            specs, float(np.mean([r.true_len for r in probe])), load=0.85)
        reqs = _trace(2000, pattern="bursty", rate=rate, seed=12,
                      slo_factor=6.0, slo_floor=100.0)
        rr = Cluster(specs, Policy("fcfs", "max", max_seq_len=512),
                     router="round_robin", predictor=LatentOracle()).run(reqs)
        prod = Cluster(specs, QPOL, router="psq", predictor=LatentOracle(),
                       rebalance_every=50, steal="quantile").run(reqs)
        assert prod.p99_latency < rr.p99_latency
        assert prod.slo_violations + prod.timed_out \
            < rr.slo_violations + rr.timed_out
        assert prod.goodput > rr.goodput


class TestRequestCopy:
    def test_fresh_copy_round_trip(self):
        """fresh_copy preserves every identity/trace field (including ones
        added after the copy helper was written — it enumerates dataclass
        fields), shares phi, and resets engine bookkeeping."""
        r = Request(rid=7, arrival=3.5, prompt_len=64, true_len=200,
                    phi=np.arange(4.0), predicted_len=180.0,
                    reserve_len=220.0, setting="qwen/math", deadline=903.5,
                    replica=2, t_start=10.0, t_finish=250.0,
                    t_first_token=12.0, generated=200, overflows=3)
        c = r.fresh_copy()
        reset = dict(replica=None, t_start=None, t_finish=None,
                     t_first_token=None, generated=0, overflows=0)
        for f in dataclasses.fields(Request):
            want = reset[f.name] if f.name in reset else getattr(r, f.name)
            got = getattr(c, f.name)
            if isinstance(want, np.ndarray):
                assert got is want          # phi stays shared, not deep-copied
            else:
                assert got == want, f.name
        assert c is not r

    def test_run_does_not_mutate_caller_requests(self):
        reqs = _trace(100, rate=1.5, seed=14, slo_factor=4.0, slo_floor=50.0)
        before = [(r.rid, r.t_start, r.t_finish, r.generated, r.replica,
                   r.reserve_len, r.deadline) for r in reqs]
        Cluster(HET_SPECS, QPOL, router="psq", predictor=LatentOracle(),
                rebalance_every=30).run(reqs)
        after = [(r.rid, r.t_start, r.t_finish, r.generated, r.replica,
                  r.reserve_len, r.deadline) for r in reqs]
        assert before == after


class TestUndersizedReplica:
    def test_oversized_request_dropped_not_wedged(self):
        """A queued request needing more KV than the replica's entire pool is
        dropped when it surfaces, instead of head-of-line blocking forever."""
        pol = Policy("fcfs", "quantile", max_seq_len=4096)
        big = Request(rid=0, arrival=0.0, prompt_len=256, true_len=100,
                      reserve_len=3000.0, predicted_len=100.0)
        ok = Request(rid=1, arrival=1.0, prompt_len=16, true_len=50,
                     reserve_len=100.0, predicted_len=50.0)
        for vec in (True, False):
            st = SimEngine(policy=pol, spec=ReplicaSpec(2, 1000),
                           vectorized=vec).run([big, ok], max_steps=50_000)
            assert st.dropped == 1
            assert st.completed == 1
            assert st.makespan < 10_000     # terminated, no max_steps spin

    def test_router_avoids_undersized_replica(self):
        """Load-aware routers never send a request to a replica whose whole
        KV pool cannot hold it while a fitting replica exists — every
        request completes even with a tiny replica in the fleet."""
        specs = (ReplicaSpec(8, 8 * (256 + 512)), ReplicaSpec(2, 500))
        reqs = _trace(200, rate=1.0, seed=3)
        for router in ("jsq", "least_kv", "psq"):
            st = Cluster(specs, QPOL, router=router,
                         predictor=LatentOracle()).run(reqs)
            assert st.completed == len(reqs), router
            assert st.dropped == 0, router

    def test_round_robin_vec_matches_ref_with_drops(self):
        """round_robin stays capacity-blind, so oversized requests DO land on
        the tiny replica and take the drop path — which must be bit-identical
        between the vectorized and reference engines."""
        specs = (ReplicaSpec(4, 2 * (256 + 512)), ReplicaSpec(2, 500))
        reqs = _trace(250, pattern="bursty", rate=1.5, seed=11)
        rows = {}
        for vec in (True, False):
            cl = Cluster(specs, QPOL, router="round_robin",
                         predictor=LatentOracle(), vectorized=vec)
            rows[vec] = cl.run(reqs).row()
        assert rows[True] == rows[False]
        assert rows[True]["dropped"] > 0
        assert rows[True]["completed"] + rows[True]["dropped"] == len(reqs)

    def test_steal_respects_thief_capacity(self):
        """Stealing never migrates a request whose reservation need exceeds
        the thief's whole KV pool."""
        specs = (ReplicaSpec(8, 8 * (256 + 512), speed=1),
                 ReplicaSpec(2, 500, speed=4))
        reqs = _trace(300, pattern="bursty", rate=1.5, seed=6)
        moved_needs = []
        orig = SimEngine.steal_queued

        def spy(self, k, mode="tail", fit=None, fit_page_size=1):
            out = orig(self, k, mode, fit, fit_page_size)
            moved_needs.extend(
                (int(r.prompt_len + r.reserve_len), fit) for r in out)
            return out

        SimEngine.steal_queued = spy
        try:
            st = Cluster(specs, QPOL, router="psq", predictor=LatentOracle(),
                         rebalance_every=20, steal="quantile").run(reqs)
        finally:
            SimEngine.steal_queued = orig
        assert moved_needs                    # stealing actually happened
        assert all(need <= fit for need, fit in moved_needs)
        assert st.completed + st.dropped == len(reqs)


class TestStealSizing:
    def test_stealing_fires_under_normalized_imbalance(self):
        """A fast replica next to a slow one with equal raw queue lengths is
        still 4x less loaded per unit of service rate; the normalized steal
        size must fire there (the raw (qd-qt)/2 rule silently no-ops)."""
        specs = (ReplicaSpec(2, 2 * (256 + 512), speed=1),
                 ReplicaSpec(8, 8 * (256 + 512), speed=4))
        reqs = _trace(500, pattern="bursty", rate=2.0, seed=15)
        off = Cluster(specs, QPOL, router="round_robin",
                      predictor=LatentOracle()).run(reqs)
        on = Cluster(specs, QPOL, router="round_robin",
                     predictor=LatentOracle(), rebalance_every=20).run(reqs)
        assert on.stolen > 0
        assert on.completed == off.completed == len(reqs)
        assert on.p99_latency < off.p99_latency
        assert on.makespan < off.makespan
        # NOTE: `balance` (max/mean tokens per replica) legitimately rises —
        # near-equal token counts on 4x-unequal hardware were the pathology


class TestDegenerateRequests:
    def test_zero_length_request_finishes_identically_both_paths(self):
        """A directly-constructed true_len=0 request (trace lengths are
        clipped above 0) must finish immediately without emitting, in both
        decode paths, instead of livelocking the reference loop."""
        pol = Policy("fcfs", "quantile", max_seq_len=512)
        rows = {}
        for vec in (True, False):
            reqs = [Request(rid=0, arrival=0.0, prompt_len=8, true_len=0,
                            reserve_len=16.0, predicted_len=1.0),
                    Request(rid=1, arrival=0.5, prompt_len=8, true_len=20,
                            reserve_len=32.0, predicted_len=20.0)]
            st = SimEngine(policy=pol, spec=ReplicaSpec(2, 1000),
                           vectorized=vec).run(reqs, max_steps=5000)
            rows[vec] = st.row()
            assert st.completed == 2
            assert st.makespan < 100
        assert rows[True] == rows[False]

    def test_engine_requires_policy_and_dims(self):
        with pytest.raises(ValueError):
            SimEngine(spec=ReplicaSpec(2, 1000))            # no policy
        with pytest.raises(ValueError):
            SimEngine(max_slots=2,
                      policy=Policy("fcfs", "max"))          # no kv_budget
