"""Cluster simulator: arrival traces, router/engine invariants, and the
vectorized-vs-reference SimEngine regression."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.data.lengths import LengthLaw, law_quantile, sample_lengths
from repro.serving.arrivals import (LatentOracle, TraceConfig, arrival_times,
                                    make_trace)
from repro.serving.cluster import Cluster, ROUTERS
from repro.serving.engine import SimEngine
from repro.serving.scheduler import Policy

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def _trace(n=300, pattern="poisson", rate=1.0, seed=0, **kw):
    kw.setdefault("max_seq_len", 512)
    kw.setdefault("model", "llama")
    kw.setdefault("scenario", "math")
    return make_trace(TraceConfig(n_requests=n, pattern=pattern, rate=rate,
                                  seed=seed, **kw))


QPOL = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)


class TestArrivals:
    def test_trace_deterministic(self):
        a = _trace(200, seed=5)
        b = _trace(200, seed=5)
        assert [(r.rid, r.arrival, r.prompt_len, r.true_len) for r in a] == \
               [(r.rid, r.arrival, r.prompt_len, r.true_len) for r in b]
        np.testing.assert_array_equal(np.stack([r.phi for r in a]),
                                      np.stack([r.phi for r in b]))

    def test_mix_covers_all_eight_settings(self):
        reqs = _trace(2000, model="mix", scenario="mix")
        assert len({r.setting for r in reqs}) == 8

    def test_lengths_heavy_tailed(self):
        reqs = _trace(2000, model="qwen", scenario="chat", max_seq_len=1 << 16)
        L = np.array([r.true_len for r in reqs])
        assert L.max() / np.median(L) > 4.0  # paper: multi-x tail draws

    def test_bursty_more_variable_than_poisson(self):
        cfg_p = TraceConfig(n_requests=4000, pattern="poisson", rate=1.0)
        cfg_b = TraceConfig(n_requests=4000, pattern="bursty", rate=1.0)
        rng = np.random.default_rng(0)
        gaps_p = np.diff(arrival_times(cfg_p, rng))
        gaps_b = np.diff(arrival_times(cfg_b, np.random.default_rng(0)))
        cv = lambda x: x.std() / x.mean()
        assert cv(gaps_b) > 1.5 * cv(gaps_p)

    def test_diurnal_modulates_rate(self):
        cfg = TraceConfig(n_requests=6000, pattern="diurnal", rate=1.0,
                          diurnal_period=4000.0, diurnal_amp=0.8)
        ts = arrival_times(cfg, np.random.default_rng(0))
        phase = np.mod(ts, cfg.diurnal_period) / cfg.diurnal_period
        peak = np.sum((phase > 0.05) & (phase < 0.45))    # sin > 0 half
        trough = np.sum((phase > 0.55) & (phase < 0.95))  # sin < 0 half
        assert peak > 1.5 * trough

    def test_mean_rate_preserved_by_patterns(self):
        for pattern in ("poisson", "bursty", "diurnal"):
            # short diurnal period so the trace spans many full cycles (the
            # rate is only mean-preserving over whole periods)
            cfg = TraceConfig(n_requests=20_000, pattern=pattern, rate=2.0,
                              diurnal_period=500.0)
            ts = arrival_times(cfg, np.random.default_rng(1))
            rate = len(ts) / ts[-1]
            assert rate == pytest.approx(2.0, rel=0.25), pattern


class TestLatentOracle:
    def test_quantiles_monotone_and_above_median(self):
        reqs = _trace(500, model="qwen", scenario="longseq")
        phi = np.stack([r.phi for r in reqs])
        o = LatentOracle()
        q50, q90, q99 = (o.quantile(phi, q) for q in (0.5, 0.9, 0.99))
        assert np.all(q50 <= q90 + 1e-6) and np.all(q90 <= q99 + 1e-6)
        med = o.predict(phi)
        assert np.mean(q90 > med) > 0.95  # body+tail q90 sits above median

    def test_law_quantile_matches_empirical(self):
        law = LengthLaw(median_scale=200, median_spread=0.5, sigma_body=0.15,
                        tail_weight=0.05, tail_alpha=2.5)
        lat = np.array([[np.log(200.0), 0.15, 0.05, 2.5]])
        rng = np.random.default_rng(0)
        draws = sample_lengths(rng, lat, 200_000, law)[0]
        for q in (0.5, 0.9, 0.99):
            got = float(law_quantile(lat, q)[0])
            want = float(np.quantile(draws, q))
            assert got == pytest.approx(want, rel=0.05), q


def _row_and_finishes(engine_or_cluster, reqs):
    stv = engine_or_cluster.run(reqs)
    if hasattr(engine_or_cluster, "engines"):
        done = [r for e in engine_or_cluster.engines for r in e.done]
    else:
        done = engine_or_cluster.done
    return stv.row(), sorted((r.rid, r.t_start, r.t_finish) for r in done)


class TestVectorizedRegression:
    @pytest.mark.parametrize("pol", [
        Policy("fcfs", "max", max_seq_len=512),
        Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512),
        Policy("sjf_pred", "predicted", margin=1.1, max_seq_len=512),
        Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
               preempt=True),
    ])
    def test_engine_vec_matches_ref(self, pol):
        """The NumPy fast path (incl. event leap) must reproduce the per-slot
        reference decode bit-for-bit: same stats, same per-request timings."""
        reqs = _trace(150, pattern="bursty", rate=0.8, seed=7)
        oracle = LatentOracle()
        kv = 3 * (256 + 512)
        ra, fa = _row_and_finishes(
            SimEngine(6, kv, pol, predictor=oracle, vectorized=True), reqs)
        rb, fb = _row_and_finishes(
            SimEngine(6, kv, pol, predictor=oracle, vectorized=False), reqs)
        assert ra == rb
        assert fa == fb

    @pytest.mark.parametrize("router", ROUTERS)
    def test_cluster_vec_matches_ref(self, router):
        reqs = _trace(200, pattern="bursty", rate=1.2, seed=11)
        oracle = LatentOracle()
        ra, fa = _row_and_finishes(
            Cluster(3, 4, 2 * (256 + 512), QPOL, router=router,
                    predictor=oracle, vectorized=True), reqs)
        rb, fb = _row_and_finishes(
            Cluster(3, 4, 2 * (256 + 512), QPOL, router=router,
                    predictor=oracle, vectorized=False), reqs)
        assert ra == rb
        assert fa == fb

    @given(st.integers(0, 10_000))
    def test_engine_vec_matches_ref_random(self, seed):
        reqs = _trace(60, pattern="poisson", rate=0.6, seed=seed)
        pol = Policy("fcfs", "quantile", quantile=0.85, max_seq_len=512)
        kv = 2 * (256 + 512)
        ra, fa = _row_and_finishes(
            SimEngine(4, kv, pol, predictor=LatentOracle(),
                      vectorized=True), reqs)
        rb, fb = _row_and_finishes(
            SimEngine(4, kv, pol, predictor=LatentOracle(),
                      vectorized=False), reqs)
        assert ra == rb and fa == fb


class TestClusterInvariants:
    def _run(self, router="psq", n=600, seed=0):
        reqs = _trace(n, pattern="bursty", rate=1.5, seed=seed)
        cl = Cluster(4, 4, 2 * (256 + 512), QPOL, router=router,
                     predictor=LatentOracle())
        stats = cl.run(reqs)
        return cl, stats, reqs

    def test_every_request_completes_exactly_once(self):
        cl, stats, reqs = self._run()
        done = [r for e in cl.engines for r in e.done]
        assert stats.completed == len(reqs) == len(done)
        assert {r.rid for r in done} == {r.rid for r in reqs}

    def test_each_request_assigned_one_replica(self):
        cl, _, reqs = self._run(router="least_kv")
        for e_idx, e in enumerate(cl.engines):
            assert all(r.replica == e_idx for r in e.done)

    def test_kv_pages_conserved_per_replica(self):
        cl, _, _ = self._run()
        for e in cl.engines:
            assert e.kv.reserved_now == 0          # all reservations released
            assert e.kv.reserved == {}             # scalar/dict in sync
            assert e.kv.peak_reserved <= e.kv.budget_tokens
            assert 0.0 <= e.kv.waste_ratio <= 1.0

    def test_deterministic_replay(self):
        _, sa, _ = self._run(seed=3)
        _, sb, _ = self._run(seed=3)
        assert sa.row() == sb.row()

    def test_round_robin_spreads_requests(self):
        cl, _, reqs = self._run(router="round_robin")
        counts = [len(e.done) for e in cl.engines]
        assert max(counts) - min(counts) <= 1


class TestEngineStepInvariants:
    def test_no_slot_double_occupancy_and_budget(self):
        """Drive the stepwise API directly, asserting per-tick invariants:
        distinct rids in slots, slot cap, budget never exceeded, scalar
        reservation counter consistent with the per-request dict."""
        reqs = _trace(120, rate=2.0, seed=13)
        for r in reqs:
            r.reserve_len = 300.0   # pre-annotated quantile-ish reservations
        pol = Policy("fcfs", "quantile", max_seq_len=512)
        eng = SimEngine(max_slots=3, kv_budget=2500, policy=pol)
        from repro.serving.scheduler import annotate_predictions
        annotate_predictions(reqs, None, pol)
        eng.submit(reqs)
        guard = 0
        while not eng.idle and guard < 200_000:
            eng.step()
            guard += 1
            rids = [r.rid for r in eng._slots]
            assert len(rids) == len(set(rids)) == eng._n_active
            assert eng._n_active <= eng.max_slots
            assert eng.kv.reserved_now <= eng.kv.budget_tokens
            assert eng.kv.reserved_now == sum(eng.kv.reserved.values())
        assert eng.idle
        assert len(eng.done) == len(reqs)


class TestDeadlockRecovery:
    def test_kv_exhaustion_does_not_livelock(self):
        """All slots stalled on grows the budget can't satisfy must trigger
        OOM eviction (progress-keeping preemption), not an infinite stall:
        every request still completes, in both decode paths, identically."""
        reqs = _trace(250, pattern="bursty", rate=1.2, seed=3,
                      model="mix", scenario="mix")
        pol = Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=512,
                     preempt=True)
        rows = {}
        for vec in (True, False):
            eng = SimEngine(4, 2 * (256 + 512), pol, predictor=LatentOracle(),
                            vectorized=vec)
            stats = eng.run(reqs, max_steps=500_000)
            assert stats.completed == len(reqs)
            assert stats.oom_evictions > 0       # the deadlock was hit+broken
            assert eng.kv.reserved_now == 0
            rows[vec] = stats.row()
        assert rows[True] == rows[False]

    def test_unservable_request_is_dropped_not_livelocked(self):
        """A request needing more KV than the entire pool can never finish;
        it must be dropped (after its reservation ask hits the pool cap)
        instead of cycling evict/admit until max_steps."""
        from repro.serving.request import Request
        big = Request(rid=0, arrival=0.0, prompt_len=256, true_len=2000,
                      reserve_len=300.0, predicted_len=300.0)
        ok = Request(rid=1, arrival=1.0, prompt_len=32, true_len=100,
                     reserve_len=150.0, predicted_len=100.0)
        pol = Policy("fcfs", "quantile", max_seq_len=4096)
        st = SimEngine(2, 1024, pol).run([big, ok], max_steps=100_000)
        assert st.dropped == 1
        assert st.completed == 1          # the servable request still finishes
        assert st.makespan < 10_000       # terminated, not max_steps spin

    def test_eviction_ask_never_exceeds_pool(self):
        """Escalating reservation asks are clamped to the pool size, so an
        evicted request always stays admittable."""
        reqs = _trace(300, pattern="bursty", rate=2.0, seed=9,
                      model="mix", scenario="mix")
        pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=512)
        eng = SimEngine(6, 1536, pol, predictor=LatentOracle())
        st = eng.run(reqs, max_steps=500_000)
        assert st.completed + st.dropped == len(reqs)
        assert st.completed == len(reqs)  # this trace fits the pool
        assert eng.kv.reserved_now == 0

    def test_empty_run_returns_empty_stats(self):
        pol = Policy("fcfs", "quantile", max_seq_len=512)
        st = SimEngine(4, 1000, pol, predictor=LatentOracle()).run([])
        assert st.completed == 0
        cst = Cluster(2, 4, 1000, pol, router="psq",
                      predictor=LatentOracle()).run([])
        assert cst.completed == 0


class TestRouterQuality:
    def test_quantile_reservation_beats_max_reserve(self):
        """Tight KV budget: distributional reservation admits far more
        concurrency than max-reserve, cutting p99 latency AND waste."""
        reqs = _trace(800, pattern="bursty", rate=1.2, seed=2,
                      model="mix", scenario="mix")
        naive = Cluster(4, 8, 2 * (256 + 512),
                        Policy("fcfs", "max", max_seq_len=512),
                        router="round_robin",
                        predictor=LatentOracle()).run(reqs)
        prod = Cluster(4, 8, 2 * (256 + 512), QPOL, router="psq",
                       predictor=LatentOracle()).run(reqs)
        assert prod.completed == naive.completed == len(reqs)
        assert prod.p99_latency < naive.p99_latency
        assert prod.kv_waste_ratio < naive.kv_waste_ratio
