"""Serving impact (beyond-paper, §4 motivation): what does ProD-quality length
prediction buy the scheduler? Compares FCFS/max-reserve (vLLM-naive),
ProD-driven SJF + quantile reservation, and the oracle upper bound, under a
KV-memory-bound regime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import scenario_pcfg
from repro.core import bins as B
from repro.core import targets as T
from repro.core.predictor import train_predictor
from repro.data import make_scenario
from repro.serving.engine import SimEngine
from repro.serving.request import workload_from_scenario
from repro.serving.scheduler import Policy

POLICIES = (
    Policy("fcfs", "max", max_seq_len=2048),
    Policy("fcfs", "predicted", max_seq_len=2048),
    Policy("sjf_pred", "predicted", max_seq_len=2048),
    Policy("sjf_pred", "quantile", quantile=0.9, max_seq_len=2048),
    Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=2048,
           preempt=True),
    Policy("sjf_oracle", "oracle", max_seq_len=2048),
)


def run(model="qwen", scen="chat", n_requests=250, fast=True, seed=0,
        verbose=True):
    data = make_scenario(model, scen, n_train=800 if fast else None,
                         n_test=max(400, n_requests), seed=seed,
                         full_paper_splits=not fast)
    pcfg = scenario_pcfg(data, epochs=15 if fast else 30)
    edges = B.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = T.dist_target(jnp.asarray(data.len_train, jnp.float32), edges)
    pred = train_predictor(jax.random.PRNGKey(seed),
                           jnp.asarray(data.phi_train["last"]), tgt, pcfg, edges)
    reqs = workload_from_scenario(data, n_requests, seed=seed, arrival_rate=3.0)
    # memory-bound regime: budget ~8 full reservations
    kv_budget = 8 * (128 + 2048)
    rows = []
    for pol in POLICIES:
        st = SimEngine(max_slots=64, kv_budget=kv_budget, policy=pol,
                       predictor=pred).run(reqs)
        rows.append(st.row())
        if verbose:
            print(f"  {st.policy:24s} lat={st.mean_latency:9.1f} "
                  f"p90={st.p90_latency:9.1f} thr={st.throughput:6.2f} "
                  f"waste={st.kv_waste_ratio:.3f} ovf={st.overflow_events} "
                  f"peak={st.peak_reserved}")
    return rows


def validate(rows) -> dict:
    by = {r["policy"]: r for r in rows}
    naive = by["fcfs+max"]
    prod = by["sjf_pred+quantile"]
    srtf = by.get("srtf_pred+quantile", prod)
    oracle = by["sjf_oracle+oracle"]
    return {
        "prod_beats_naive_latency": prod["mean_latency"] < naive["mean_latency"],
        "prod_latency_gain_pct": 100 * (naive["mean_latency"] - prod["mean_latency"])
        / naive["mean_latency"],
        "prod_reduces_waste": prod["kv_waste_ratio"] < naive["kv_waste_ratio"],
        "oracle_is_bound": oracle["mean_latency"] <= prod["mean_latency"] * 1.05,
        "prod_throughput_gain_pct": 100 * (prod["throughput"] - naive["throughput"])
        / max(naive["throughput"], 1e-9),
        "srtf_not_worse_than_sjf": srtf["mean_latency"]
        <= prod["mean_latency"] * 1.05,
        "srtf_preemptions": srtf.get("preemptions", 0),
    }


def main(fast=True):
    rows = run(fast=fast)
    print("checks:", validate(rows))
    return rows


if __name__ == "__main__":
    main()
